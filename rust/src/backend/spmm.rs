//! Structured SpMM over the compressed N:M layout: `Y = X · Wᵀ` with `W`
//! stored as (values, packed offsets) — the computational core of SLoPe's
//! FWD and BWD-2.
//!
//! The N:M structure is what makes this fast: within a group of M dense
//! columns the kernel touches exactly N values whose intra-group offsets
//! are decoded inline from the Eq.-7 bit-packed metadata plane
//! (`ceil(log2 M)` bits per kept value — 8× less metadata traffic than
//! the old `u16` absolute indices for 2:4).  For the 2:4 hot path one
//! metadata byte holds four offsets (two whole groups), so the kernel
//! decodes **whole bytes** through a 256-entry table ([`sparse_dot`] →
//! the `DECODE24` LUT) instead of per-element shift/mask; the scalar
//! reference decode ([`sparse_dot_scalar`]) is kept and pinned
//! bit-identical by the property suite.  The inner loop is a short
//! gather-multiply-accumulate with perfect value locality — the CPU
//! analogue of the metadata decode sparse tensor cores do in hardware.
//! Compared to the dense `gemm_nt`, it performs `N/M` of the
//! multiply-adds and streams `N/M` of the weight bytes.
//!
//! Kernels run on the persistent [`crate::backend::pool`] engine and
//! honor the policy's [`PartitionStrategy`]: **batch rows** are split
//! when the batch saturates the pool, **output columns** (weight rows)
//! are striped when it cannot — the `batch = 1` serving shape.  Either
//! way every output element is one group-ascending reduction, so results
//! are bit-identical to serial at any thread count and `spmm_rowmajor` /
//! `spmm_tiled` agree bit-for-bit with each other (tiling and striping
//! only reorder whole elements).
//!
//! Every entry point dispatches through a [`SimdLevel`]
//! ([`crate::backend::simd`]): on AVX2+FMA hardware the 2:4 inner loop
//! runs the lane-permute gather-dot ([`crate::backend::simd::x86::sparse_dot24`],
//! eight FMAs per metadata-byte pair), everywhere else — and under
//! `SLOPE_SIMD=scalar` — the original safe-Rust kernels run unchanged.
//! Within a level every output element is computed by the same
//! per-element function regardless of partition or traversal, so the
//! bit-identical-across-threads contract holds at **both** levels;
//! `Avx2` vs `Scalar` agree to tight tolerance (FMA reassociation) and
//! bitwise on small-integer inputs (`tests/simd_parity.rs`).
//!
//! The [`spmm_prepacked`] family runs the same contract over the fused
//! [`PrepackedNm`] operand ([`crate::sparsity::prepacked`]): values
//! interleaved with pre-decoded permute lanes in one stream, consumed on
//! AVX2 by the register-blocked four-row micro-tile
//! ([`crate::backend::simd::x86::spmm_pre24_x4`] — each `x` window
//! loaded once for four outputs) and on scalar by fused-stream twins of
//! the table-driven blocks.  At a given level every output element's
//! reduction order is **identical** to the compressed-plane kernel's, so
//! prepacked output is bit-identical to `spmm_rowmajor*` — across
//! threads, partitions, and traversals (pinned in `tests/simd_parity.rs`).

use crate::backend::pool::{parallel_over_col_stripes, parallel_over_rows, ParallelPolicy,
                           Partition, StripedOut};
use crate::backend::simd::{self, SimdLevel};
use crate::sparsity::prepacked::unpack_offset_slots;
use crate::sparsity::{compressed::unpack_offset, CompressedNm, PrepackedNm};
use crate::tensor::Matrix;
use std::ops::Range;

/// Execution strategy for SpMM (the §2.4 tiling ablation toggle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpmmAlgo {
    /// Straight row-major traversal.
    RowMajor,
    /// Square output tiles of the given edge (paper's upsample tiling).
    Tiled { tile: usize },
}

// ---- row-major --------------------------------------------------------

/// `Y[b, o] = Σ_k X[b, col(o,k)] · vals[o,k]` — row-major traversal,
/// serial (the seed API).
pub fn spmm_rowmajor(x: &Matrix, w: &CompressedNm) -> Matrix {
    spmm_rowmajor_with(x, w, &ParallelPolicy::serial())
}

/// Row-major SpMM, parallel per the policy's partition strategy.
pub fn spmm_rowmajor_with(x: &Matrix, w: &CompressedNm, policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_rowmajor_into(x, w, &mut y, policy);
    y
}

/// Allocating row-major SpMM at an explicit [`SimdLevel`].
pub fn spmm_rowmajor_with_at(level: SimdLevel, x: &Matrix, w: &CompressedNm,
                             policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_rowmajor_into_at(level, x, w, &mut y, policy);
    y
}

/// Row-major SpMM into a caller-owned output (overwritten; every element
/// is stored, so no pre-zeroing is needed) — dispatched at the
/// process-wide [`simd_level`](crate::backend::simd::simd_level).
pub fn spmm_rowmajor_into(x: &Matrix, w: &CompressedNm, y: &mut Matrix, policy: &ParallelPolicy) {
    spmm_rowmajor_into_at(simd::simd_level(), x, w, y, policy);
}

/// Row-major SpMM at an explicit [`SimdLevel`] (clamped to what the
/// hardware supports) — the hook parity tests and level-pinned benches
/// use.
///
/// §Perf iteration (EXPERIMENTS.md §Perf/L3): scalar gathers don't
/// auto-vectorize, so the scalar path processes FOUR weight rows per
/// pass — the four accumulator chains give the out-of-order core
/// independent gather streams (ILP) and reuse the cached x row.  The
/// AVX2 path instead vectorizes within each row's reduction.
pub fn spmm_rowmajor_into_at(level: SimdLevel, x: &Matrix, w: &CompressedNm, y: &mut Matrix,
                             policy: &ParallelPolicy) {
    let level = simd::effective(level);
    assert_eq!(x.cols, w.cols, "spmm: x cols must equal dense weight cols");
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "spmm output shape");
    match policy.resolve(x.rows, w.rows) {
        Partition::Serial => spmm_rowmajor_rows(level, x, w, 0..x.rows, &mut y.data),
        Partition::Rows(_) => {
            parallel_over_rows(policy, &mut y.data, w.rows, |range, chunk| {
                spmm_rowmajor_rows(level, x, w, range, chunk);
            });
        }
        Partition::Cols(tasks) => {
            let out = StripedOut::new(&mut y.data, w.rows);
            parallel_over_col_stripes(tasks, w.rows, |stripe| {
                for b in 0..x.rows {
                    // SAFETY: this task's stripe is disjoint from every
                    // other task's (pool partition contract).
                    let dst = unsafe { out.row_stripe(b, stripe.clone()) };
                    spmm_row_block(level, x.row(b), w, stripe.clone(), dst);
                }
            });
        }
    }
}

fn spmm_rowmajor_rows(level: SimdLevel, x: &Matrix, w: &CompressedNm, range: Range<usize>,
                      out: &mut [f32]) {
    for (local, b) in range.enumerate() {
        let yrow = &mut out[local * w.rows..(local + 1) * w.rows];
        spmm_row_block(level, x.row(b), w, 0..w.rows, yrow);
    }
}

/// Compute one batch row's outputs for weight rows `orange`, written to
/// `out` (`orange.len()` long).  Dispatches to the AVX2 gather-dot, the
/// table-driven scalar 2:4 block, or the generic packed-decode block.
/// Within a level every element is the same per-element reduction no
/// matter which entry point, partition, or tile reached here — the
/// invariant behind every bitwise pin in the suite.
#[inline]
fn spmm_row_block(level: SimdLevel, xrow: &[f32], w: &CompressedNm, orange: Range<usize>,
                  out: &mut [f32]) {
    if w.scheme.n == 2 && w.scheme.m == 4 {
        spmm_row_block24_at(level, xrow, w, orange, out);
    } else {
        spmm_row_block_generic(xrow, w, orange, out);
    }
}

/// Level dispatch for the 2:4 block.  Non-x86 builds only ever see
/// `Scalar` (detection and `effective` both clamp).
#[inline]
fn spmm_row_block24_at(level: SimdLevel, xrow: &[f32], w: &CompressedNm, orange: Range<usize>,
                       out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2 {
        let kc = w.kcols();
        let rmb = w.row_meta_bytes();
        for (i, o) in orange.enumerate() {
            let vals = &w.values[o * kc..(o + 1) * kc];
            let meta = &w.meta[o * rmb..(o + 1) * rmb];
            // SAFETY: `effective` verified AVX2+FMA before this level
            // could be selected; slice lengths satisfy the layout
            // invariants the kernel documents.
            out[i] = unsafe { simd::x86::sparse_dot24(xrow, vals, meta) };
        }
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = level;
    spmm_row_block24(xrow, w, orange, out);
}

fn spmm_row_block_generic(xrow: &[f32], w: &CompressedNm, orange: Range<usize>, out: &mut [f32]) {
    let kc = w.kcols();
    let rmb = w.row_meta_bytes();
    let (n, m) = (w.scheme.n, w.scheme.m);
    let bits = w.scheme.offset_bits();
    let groups = if n == 0 { 0 } else { kc / n };
    let len = orange.len();
    let quads = len / 4 * 4;
    let mut i = 0;
    while i < quads {
        let o = orange.start + i;
        let v = &w.values[o * kc..(o + 4) * kc];
        let (v0, v1, v2, v3) = (&v[..kc], &v[kc..2 * kc], &v[2 * kc..3 * kc], &v[3 * kc..]);
        let mt = &w.meta[o * rmb..(o + 4) * rmb];
        let (m0, m1, m2, m3) =
            (&mt[..rmb], &mt[rmb..2 * rmb], &mt[2 * rmb..3 * rmb], &mt[3 * rmb..]);
        let mut acc = [0.0f32; 4];
        let mut k = 0;
        let mut base = 0;
        for _ in 0..groups {
            for j in 0..n {
                acc[0] += xrow[base + unpack_offset(m0, k + j, bits)] * v0[k + j];
                acc[1] += xrow[base + unpack_offset(m1, k + j, bits)] * v1[k + j];
                acc[2] += xrow[base + unpack_offset(m2, k + j, bits)] * v2[k + j];
                acc[3] += xrow[base + unpack_offset(m3, k + j, bits)] * v3[k + j];
            }
            k += n;
            base += m;
        }
        out[i..i + 4].copy_from_slice(&acc);
        i += 4;
    }
    for i in quads..len {
        let o = orange.start + i;
        let vals = &w.values[o * kc..(o + 1) * kc];
        let meta = &w.meta[o * rmb..(o + 1) * rmb];
        out[i] = sparse_dot_scalar(xrow, vals, meta, n, m, bits);
    }
}

/// 256-entry whole-byte decode table for 2:4 metadata: byte → four 2-bit
/// intra-group offsets, LSB-first (offsets `k, k+1` of one group in the
/// low nibble, `k+2, k+3` of the next group in the high nibble).
const DECODE24: [[u8; 4]; 256] = build_decode24();

const fn build_decode24() -> [[u8; 4]; 256] {
    let mut t = [[0u8; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = [(b & 3) as u8, ((b >> 2) & 3) as u8, ((b >> 4) & 3) as u8, ((b >> 6) & 3) as u8];
        b += 1;
    }
    t
}

/// 2:4 specialization of the four-row block: one table lookup decodes a
/// whole metadata byte (two groups, four kept values) per weight row.
fn spmm_row_block24(xrow: &[f32], w: &CompressedNm, orange: Range<usize>, out: &mut [f32]) {
    let kc = w.kcols();
    let rmb = w.row_meta_bytes();
    let pairs = kc / 4; // full metadata bytes per row (2 groups each)
    let len = orange.len();
    let quads = len / 4 * 4;
    let mut i = 0;
    while i < quads {
        let o = orange.start + i;
        let v = &w.values[o * kc..(o + 4) * kc];
        let (v0, v1, v2, v3) = (&v[..kc], &v[kc..2 * kc], &v[2 * kc..3 * kc], &v[3 * kc..]);
        let mt = &w.meta[o * rmb..(o + 4) * rmb];
        let (m0, m1, m2, m3) =
            (&mt[..rmb], &mt[rmb..2 * rmb], &mt[2 * rmb..3 * rmb], &mt[3 * rmb..]);
        let mut acc = [0.0f32; 4];
        let mut k = 0;
        let mut base = 0;
        for byte in 0..pairs {
            let d0 = DECODE24[m0[byte] as usize];
            let d1 = DECODE24[m1[byte] as usize];
            let d2 = DECODE24[m2[byte] as usize];
            let d3 = DECODE24[m3[byte] as usize];
            acc[0] += xrow[base + d0[0] as usize] * v0[k];
            acc[0] += xrow[base + d0[1] as usize] * v0[k + 1];
            acc[0] += xrow[base + 4 + d0[2] as usize] * v0[k + 2];
            acc[0] += xrow[base + 4 + d0[3] as usize] * v0[k + 3];
            acc[1] += xrow[base + d1[0] as usize] * v1[k];
            acc[1] += xrow[base + d1[1] as usize] * v1[k + 1];
            acc[1] += xrow[base + 4 + d1[2] as usize] * v1[k + 2];
            acc[1] += xrow[base + 4 + d1[3] as usize] * v1[k + 3];
            acc[2] += xrow[base + d2[0] as usize] * v2[k];
            acc[2] += xrow[base + d2[1] as usize] * v2[k + 1];
            acc[2] += xrow[base + 4 + d2[2] as usize] * v2[k + 2];
            acc[2] += xrow[base + 4 + d2[3] as usize] * v2[k + 3];
            acc[3] += xrow[base + d3[0] as usize] * v3[k];
            acc[3] += xrow[base + d3[1] as usize] * v3[k + 1];
            acc[3] += xrow[base + 4 + d3[2] as usize] * v3[k + 2];
            acc[3] += xrow[base + 4 + d3[3] as usize] * v3[k + 3];
            k += 4;
            base += 8;
        }
        if k < kc {
            // Odd group count: the last byte's low nibble holds one group.
            let d0 = DECODE24[m0[pairs] as usize];
            let d1 = DECODE24[m1[pairs] as usize];
            let d2 = DECODE24[m2[pairs] as usize];
            let d3 = DECODE24[m3[pairs] as usize];
            acc[0] += xrow[base + d0[0] as usize] * v0[k];
            acc[0] += xrow[base + d0[1] as usize] * v0[k + 1];
            acc[1] += xrow[base + d1[0] as usize] * v1[k];
            acc[1] += xrow[base + d1[1] as usize] * v1[k + 1];
            acc[2] += xrow[base + d2[0] as usize] * v2[k];
            acc[2] += xrow[base + d2[1] as usize] * v2[k + 1];
            acc[3] += xrow[base + d3[0] as usize] * v3[k];
            acc[3] += xrow[base + d3[1] as usize] * v3[k + 1];
        }
        out[i..i + 4].copy_from_slice(&acc);
        i += 4;
    }
    for i in quads..len {
        let o = orange.start + i;
        let vals = &w.values[o * kc..(o + 1) * kc];
        let meta = &w.meta[o * rmb..(o + 1) * rmb];
        out[i] = sparse_dot24(xrow, vals, meta);
    }
}

// ---- prepacked --------------------------------------------------------

/// SpMM over the fused prepacked plane, serial.
pub fn spmm_prepacked(x: &Matrix, w: &PrepackedNm) -> Matrix {
    spmm_prepacked_with(x, w, &ParallelPolicy::serial())
}

/// Prepacked SpMM, parallel per the policy's partition strategy.
pub fn spmm_prepacked_with(x: &Matrix, w: &PrepackedNm, policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_prepacked_into(x, w, &mut y, policy);
    y
}

/// Allocating prepacked SpMM at an explicit [`SimdLevel`].
pub fn spmm_prepacked_with_at(level: SimdLevel, x: &Matrix, w: &PrepackedNm,
                              policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_prepacked_into_at(level, x, w, &mut y, policy);
    y
}

/// Prepacked SpMM into a caller-owned output (overwritten) at the
/// process-wide level.
pub fn spmm_prepacked_into(x: &Matrix, w: &PrepackedNm, y: &mut Matrix,
                           policy: &ParallelPolicy) {
    spmm_prepacked_into_at(simd::simd_level(), x, w, y, policy);
}

/// Prepacked SpMM at an explicit [`SimdLevel`] (clamped to hardware).
/// Partitioning mirrors [`spmm_rowmajor_into_at`] exactly — same
/// `resolve`, same row split, same quad-aligned column stripes — and the
/// per-element reduction at a given level is identical to the
/// compressed-plane kernel's, so output is bit-identical to
/// `spmm_rowmajor*` for any thread count or partition.
pub fn spmm_prepacked_into_at(level: SimdLevel, x: &Matrix, w: &PrepackedNm, y: &mut Matrix,
                              policy: &ParallelPolicy) {
    let level = simd::effective(level);
    assert_eq!(x.cols, w.cols, "spmm: x cols must equal dense weight cols");
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "spmm output shape");
    match policy.resolve(x.rows, w.rows) {
        Partition::Serial => spmm_prepacked_rows(level, x, w, 0..x.rows, &mut y.data),
        Partition::Rows(_) => {
            parallel_over_rows(policy, &mut y.data, w.rows, |range, chunk| {
                spmm_prepacked_rows(level, x, w, range, chunk);
            });
        }
        Partition::Cols(tasks) => {
            let out = StripedOut::new(&mut y.data, w.rows);
            parallel_over_col_stripes(tasks, w.rows, |stripe| {
                for b in 0..x.rows {
                    // SAFETY: this task's stripe is disjoint from every
                    // other task's (pool partition contract).
                    let dst = unsafe { out.row_stripe(b, stripe.clone()) };
                    spmm_pre_row_block(level, x.row(b), w, stripe.clone(), dst);
                }
            });
        }
    }
}

fn spmm_prepacked_rows(level: SimdLevel, x: &Matrix, w: &PrepackedNm, range: Range<usize>,
                       out: &mut [f32]) {
    for (local, b) in range.enumerate() {
        let yrow = &mut out[local * w.rows..(local + 1) * w.rows];
        spmm_pre_row_block(level, x.row(b), w, 0..w.rows, yrow);
    }
}

/// One batch row's outputs for prepacked weight rows `orange` — the
/// fused-stream counterpart of [`spmm_row_block`].  AVX2 2:4 runs the
/// four-row register-blocked micro-tile with a per-dot remainder;
/// everything else runs the scalar fused-stream twins.  Per element the
/// reduction order equals the compressed path's at the same level.
#[inline]
fn spmm_pre_row_block(level: SimdLevel, xrow: &[f32], w: &PrepackedNm, orange: Range<usize>,
                      out: &mut [f32]) {
    if w.is_fused24() {
        #[cfg(target_arch = "x86_64")]
        if level == SimdLevel::Avx2 {
            let kc = w.kcols();
            let len = orange.len();
            let quads = len / 4 * 4;
            let mut i = 0;
            while i < quads {
                let o = orange.start + i;
                // SAFETY: `effective` verified AVX2+FMA before this level
                // could be selected; each `row(o)` is a full fused row.
                unsafe {
                    simd::x86::spmm_pre24_x4(
                        xrow,
                        [w.row(o), w.row(o + 1), w.row(o + 2), w.row(o + 3)],
                        kc,
                        &mut out[i..i + 4],
                    );
                }
                i += 4;
            }
            for i in quads..len {
                let o = orange.start + i;
                // SAFETY: as above.
                out[i] = unsafe { simd::x86::sparse_dot24_pre(xrow, w.row(o), kc) };
            }
            return;
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = level;
        spmm_pre_row_block24_scalar(xrow, w, orange, out);
    } else {
        spmm_pre_row_block_generic(xrow, w, orange, out);
    }
}

/// Scalar twin of the 2:4 prepacked block: four weight rows per pass
/// (independent accumulators = gather-stream ILP, mirroring
/// [`spmm_row_block24`]), decoding the stored lane bytes instead of the
/// LUT.  Lanes 2/3 carry the +4 window bias from prepack time, so per
/// element the adds replay [`sparse_dot24`]'s k-ascending order exactly
/// — bit-identical to the compressed scalar path.
fn spmm_pre_row_block24_scalar(xrow: &[f32], w: &PrepackedNm, orange: Range<usize>,
                               out: &mut [f32]) {
    let len = orange.len();
    let quads = len / 4 * 4;
    let mut i = 0;
    while i < quads {
        let o = orange.start + i;
        let rows = [w.row(o), w.row(o + 1), w.row(o + 2), w.row(o + 3)];
        let mut acc = [0.0f32; 4];
        for (a, row) in acc.iter_mut().zip(rows) {
            *a = sparse_dot24_pre_scalar(xrow, row, w.kcols());
        }
        out[i..i + 4].copy_from_slice(&acc);
        i += 4;
    }
    for i in quads..len {
        let o = orange.start + i;
        out[i] = sparse_dot24_pre_scalar(xrow, w.row(o), w.kcols());
    }
}

/// Generic-scheme prepacked block (1:2, 2:8, …): the packed metadata
/// bytes ride behind the row's values in the same stream; decode with
/// the same bit arithmetic as the compressed path, four rows per pass.
/// Per element this is [`sparse_dot_scalar`]'s group-ascending order —
/// bit-identical at every level (the generic scheme has no AVX2 kernel
/// on the compressed path either).
fn spmm_pre_row_block_generic(xrow: &[f32], w: &PrepackedNm, orange: Range<usize>,
                              out: &mut [f32]) {
    let kc = w.kcols();
    let (n, m) = (w.scheme.n, w.scheme.m);
    let bits = w.scheme.offset_bits();
    let groups = if n == 0 { 0 } else { kc / n };
    let len = orange.len();
    let quads = len / 4 * 4;
    let mut i = 0;
    while i < quads {
        let o = orange.start + i;
        let rows = [w.row(o), w.row(o + 1), w.row(o + 2), w.row(o + 3)];
        let metas = [&rows[0][kc..], &rows[1][kc..], &rows[2][kc..], &rows[3][kc..]];
        let mut acc = [0.0f32; 4];
        let mut k = 0;
        let mut base = 0;
        for _ in 0..groups {
            for j in 0..n {
                for e in 0..4 {
                    acc[e] += xrow[base + unpack_offset_slots(metas[e], k + j, bits)]
                        * f32::from_bits(rows[e][k + j]);
                }
            }
            k += n;
            base += m;
        }
        out[i..i + 4].copy_from_slice(&acc);
        i += 4;
    }
    for i in quads..len {
        let o = orange.start + i;
        out[i] = sparse_dot_pre_scalar(xrow, w.row(o), kc, n, m, bits);
    }
}

/// Per-dot scalar reference over one fused 2:4 row — the k-ascending add
/// order of [`sparse_dot24`], reading values and (pre-biased) lane bytes
/// from the interleaved stream.
fn sparse_dot24_pre_scalar(xrow: &[f32], row: &[u32], kc: usize) -> f32 {
    let pairs = kc / 4;
    let mut s = 0.0f32;
    let mut slot = 0;
    let mut byte = 0;
    let mut base = 0;
    while byte + 2 <= pairs {
        for half in 0..2 {
            let l = row[slot + 8 + half].to_le_bytes();
            let v = &row[slot + half * 4..slot + half * 4 + 4];
            s += xrow[base + l[0] as usize] * f32::from_bits(v[0]);
            s += xrow[base + l[1] as usize] * f32::from_bits(v[1]);
            s += xrow[base + l[2] as usize] * f32::from_bits(v[2]);
            s += xrow[base + l[3] as usize] * f32::from_bits(v[3]);
            base += 8;
        }
        slot += 10;
        byte += 2;
    }
    if byte < pairs {
        let l = row[slot + 4].to_le_bytes();
        s += xrow[base + l[0] as usize] * f32::from_bits(row[slot]);
        s += xrow[base + l[1] as usize] * f32::from_bits(row[slot + 1]);
        s += xrow[base + l[2] as usize] * f32::from_bits(row[slot + 2]);
        s += xrow[base + l[3] as usize] * f32::from_bits(row[slot + 3]);
        slot += 5;
        base += 8;
    }
    if kc % 4 == 2 {
        let l = row[slot + 2].to_le_bytes();
        s += xrow[base + l[0] as usize] * f32::from_bits(row[slot]);
        s += xrow[base + l[1] as usize] * f32::from_bits(row[slot + 1]);
    }
    s
}

/// Per-dot scalar reference over one fused generic-scheme row:
/// [`sparse_dot_scalar`]'s exact traversal with operands drawn from the
/// interleaved stream.
fn sparse_dot_pre_scalar(xrow: &[f32], row: &[u32], kc: usize, n: usize, m: usize,
                         bits: u32) -> f32 {
    let meta = &row[kc..];
    let groups = if n == 0 { 0 } else { kc / n };
    let mut s = 0.0f32;
    let mut k = 0;
    let mut base = 0;
    for _ in 0..groups {
        for j in 0..n {
            s += xrow[base + unpack_offset_slots(meta, k + j, bits)]
                * f32::from_bits(row[k + j]);
        }
        k += n;
        base += m;
    }
    s
}

// ---- tiled ------------------------------------------------------------

/// Square-tiled traversal (paper §2.4 / Appendix E), serial.
pub fn spmm_tiled(x: &Matrix, w: &CompressedNm, tile: usize) -> Matrix {
    spmm_tiled_with(x, w, tile, &ParallelPolicy::serial())
}

/// Tiled SpMM, parallel per the policy's partition strategy.
pub fn spmm_tiled_with(x: &Matrix, w: &CompressedNm, tile: usize,
                       policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_tiled_into(x, w, tile, &mut y, policy);
    y
}

/// Allocating tiled SpMM at an explicit [`SimdLevel`].
pub fn spmm_tiled_with_at(level: SimdLevel, x: &Matrix, w: &CompressedNm, tile: usize,
                          policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_tiled_into_at(level, x, w, tile, &mut y, policy);
    y
}

/// Tiled SpMM into a caller-owned output: process `tile × tile` output
/// blocks so the active slice of `X` stays cache-resident while a block
/// of weight rows streams through — the CPU analogue of splitting the
/// upsample weight into square sub-matrices for cuSPARSELt.  Workers tile
/// their own batch-row range (row split) or column stripe (column split);
/// since every output element is an independent `sparse_dot`, the
/// traversal order never changes values.
pub fn spmm_tiled_into(x: &Matrix, w: &CompressedNm, tile: usize, y: &mut Matrix,
                       policy: &ParallelPolicy) {
    spmm_tiled_into_at(simd::simd_level(), x, w, tile, y, policy);
}

/// Tiled SpMM at an explicit [`SimdLevel`] (clamped to hardware).
///
/// The **weight-row** tile edge is the caller's `tile` (the §2.4
/// ablation knob), but the **batch-row** step is derived from the
/// resolved policy ([`ParallelPolicy::tile_rows`]): a narrow batch under
/// a wide fixed tile used to collapse the traversal's row blocking
/// entirely, so the step now tracks how the pool splits the rows.  Tile
/// geometry only reorders whole elements — the derived tiling is pinned
/// bit-identical to the old fixed tiling in the tests below.
pub fn spmm_tiled_into_at(level: SimdLevel, x: &Matrix, w: &CompressedNm, tile: usize,
                          y: &mut Matrix, policy: &ParallelPolicy) {
    let level = simd::effective(level);
    assert_eq!(x.cols, w.cols);
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "spmm output shape");
    assert!(tile > 0);
    let btile = policy.tile_rows(x.rows, tile);
    match policy.resolve(x.rows, w.rows) {
        Partition::Serial => spmm_tiled_rows(level, x, w, btile, tile, 0..x.rows, &mut y.data),
        Partition::Rows(_) => {
            parallel_over_rows(policy, &mut y.data, w.rows, |range, chunk| {
                spmm_tiled_rows(level, x, w, btile, tile, range, chunk);
            });
        }
        Partition::Cols(tasks) => {
            let out = StripedOut::new(&mut y.data, w.rows);
            parallel_over_col_stripes(tasks, w.rows, |stripe| {
                spmm_tiled_cols(level, x, w, btile, tile, stripe, &out);
            });
        }
    }
}

/// Both tiled traversals delegate their inner decode loop to the shared
/// [`spmm_row_block`] dispatcher (one tile-row of outputs at a time), so
/// the SIMD path accelerates every SpMM entry point, not just
/// `spmm_rowmajor`.  Per element nothing changed: at a given level the
/// block computes exactly the per-element reduction the old inline loop
/// did, so tiled stays bitwise equal to row-major.
fn spmm_tiled_rows(level: SimdLevel, x: &Matrix, w: &CompressedNm, btile: usize, tile: usize,
                   range: Range<usize>, out: &mut [f32]) {
    let rows = range.len();
    for bt in (0..rows).step_by(btile) {
        let bend = (bt + btile).min(rows);
        for ot in (0..w.rows).step_by(tile) {
            let oend = (ot + tile).min(w.rows);
            for local in bt..bend {
                let xrow = x.row(range.start + local);
                let yrow = &mut out[local * w.rows..(local + 1) * w.rows];
                spmm_row_block(level, xrow, w, ot..oend, &mut yrow[ot..oend]);
            }
        }
    }
}

/// Column-striped tiled traversal: tile batch rows against this task's
/// stripe of weight rows, writing only inside the stripe.
fn spmm_tiled_cols(level: SimdLevel, x: &Matrix, w: &CompressedNm, btile: usize, tile: usize,
                   stripe: Range<usize>, out: &StripedOut) {
    for bt in (0..x.rows).step_by(btile) {
        let bend = (bt + btile).min(x.rows);
        for ot in (stripe.start..stripe.end).step_by(tile) {
            let oend = (ot + tile).min(stripe.end);
            for b in bt..bend {
                let xrow = x.row(b);
                // SAFETY: ot..oend lies inside this task's stripe.
                let dst = unsafe { out.row_stripe(b, ot..oend) };
                spmm_row_block(level, xrow, w, ot..oend, dst);
            }
        }
    }
}

/// Gather-dot over one compressed weight row at the process-wide level:
/// AVX2 lane-permute gather for 2:4 on capable hardware, the
/// table-driven whole-byte decode for scalar 2:4, and the packed scalar
/// decode otherwise.  At `Scalar` the result is bit-identical to
/// [`sparse_dot_scalar`] for every scheme — the property the
/// `parallel_and_packed` suite pins.
#[inline]
pub fn sparse_dot(xrow: &[f32], vals: &[f32], meta: &[u8], n: usize, m: usize, bits: u32) -> f32 {
    sparse_dot_at(simd::simd_level(), xrow, vals, meta, n, m, bits)
}

/// [`sparse_dot`] at an explicit [`SimdLevel`] (clamped to hardware).
#[inline]
pub fn sparse_dot_at(level: SimdLevel, xrow: &[f32], vals: &[f32], meta: &[u8], n: usize,
                     m: usize, bits: u32) -> f32 {
    let level = simd::effective(level);
    if n == 2 && m == 4 {
        #[cfg(target_arch = "x86_64")]
        if level == SimdLevel::Avx2 {
            // SAFETY: `effective` verified AVX2+FMA for this level.
            return unsafe { simd::x86::sparse_dot24(xrow, vals, meta) };
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = level;
        sparse_dot24(xrow, vals, meta)
    } else {
        sparse_dot_scalar(xrow, vals, meta, n, m, bits)
    }
}

/// Reference gather-dot: group-ascending traversal decoding each packed
/// intra-group offset individually (`group·M + offset`).  All loads are
/// ordinary bounds-checked slice indexing — safe rust, no `unsafe` fast
/// path; offsets are `< M` by construction at compress time, so
/// `base + offset` always lands inside `xrow`.
#[inline]
pub fn sparse_dot_scalar(xrow: &[f32], vals: &[f32], meta: &[u8], n: usize, m: usize,
                         bits: u32) -> f32 {
    let kc = vals.len();
    let groups = if n == 0 { 0 } else { kc / n };
    let mut s = 0.0f32;
    let mut k = 0;
    let mut base = 0;
    for _ in 0..groups {
        for j in 0..n {
            s += xrow[base + unpack_offset(meta, k + j, bits)] * vals[k + j];
        }
        k += n;
        base += m;
    }
    s
}

/// 2:4 gather-dot decoding whole metadata bytes through the LUT; add
/// order matches [`sparse_dot_scalar`] exactly (k-ascending).
#[inline]
fn sparse_dot24(xrow: &[f32], vals: &[f32], meta: &[u8]) -> f32 {
    let kc = vals.len();
    let pairs = kc / 4;
    let mut s = 0.0f32;
    let mut k = 0;
    let mut base = 0;
    for byte in 0..pairs {
        let d = DECODE24[meta[byte] as usize];
        s += xrow[base + d[0] as usize] * vals[k];
        s += xrow[base + d[1] as usize] * vals[k + 1];
        s += xrow[base + 4 + d[2] as usize] * vals[k + 2];
        s += xrow[base + 4 + d[3] as usize] * vals[k + 3];
        k += 4;
        base += 8;
    }
    if k < kc {
        let d = DECODE24[meta[pairs] as usize];
        s += xrow[base + d[0] as usize] * vals[k];
        s += xrow[base + d[1] as usize] * vals[k + 1];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::gemm_nt;
    use crate::backend::pool::PartitionStrategy;
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    #[test]
    fn spmm_matches_dense_on_masked_weight() {
        let mut rng = Rng::seed_from_u64(0);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let x = Matrix::randn(8, 8 * m, 1.0, &mut rng);
            let w = Matrix::randn(16, 8 * m, 1.0, &mut rng);
            let mask = random_row_mask(16, 8 * m, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            let want = gemm_nt(&x, &mask.apply(&w));
            assert!(spmm_rowmajor(&x, &c).max_abs_diff(&want) < 1e-4, "{s}");
        }
    }

    #[test]
    fn tiled_matches_rowmajor_ragged_tiles() {
        let mut rng = Rng::seed_from_u64(1);
        let x = Matrix::randn(13, 32, 1.0, &mut rng); // non-multiple rows
        let w = Matrix::randn(29, 32, 1.0, &mut rng); // non-multiple outs
        let mask = random_row_mask(29, 32, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let a = spmm_rowmajor(&x, &c);
        for tile in [1, 3, 7, 16, 64] {
            // Same sparse_dot per element ⇒ exact agreement.
            assert_eq!(spmm_tiled(&x, &c, tile), a, "tile {tile}");
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let mut rng = Rng::seed_from_u64(2);
        let x = Matrix::randn(23, 64, 1.0, &mut rng); // ragged batch
        let w = Matrix::randn(37, 64, 1.0, &mut rng); // ragged outs
        let mask = random_row_mask(37, 64, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let serial = spmm_rowmajor(&x, &c);
        let serial_t = spmm_tiled(&x, &c, 8);
        for threads in [2usize, 4, 7] {
            for strategy in
                [PartitionStrategy::Auto, PartitionStrategy::Rows, PartitionStrategy::Cols]
            {
                let p = ParallelPolicy { threads, min_rows_per_task: 1, partition: strategy };
                assert_eq!(spmm_rowmajor_with(&x, &c, &p), serial, "t={threads} {strategy:?}");
                assert_eq!(spmm_tiled_with(&x, &c, 8, &p), serial_t,
                           "tiled t={threads} {strategy:?}");
            }
        }
    }

    #[test]
    fn batch_one_col_partition_matches_serial() {
        let mut rng = Rng::seed_from_u64(3);
        let x = Matrix::randn(1, 64, 1.0, &mut rng); // the serving shape
        let w = Matrix::randn(53, 64, 1.0, &mut rng);
        let mask = random_row_mask(53, 64, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let serial = spmm_rowmajor(&x, &c);
        for threads in [2usize, 4, 7] {
            let p = ParallelPolicy {
                threads,
                min_rows_per_task: 1,
                partition: PartitionStrategy::Auto,
            };
            // Auto must pick the column split here (batch row split is a
            // single task) and still match serial exactly.
            assert_eq!(p.resolve(x.rows, w.rows), Partition::Cols(threads.min(53 / 8)));
            assert_eq!(spmm_rowmajor_with(&x, &c, &p), serial, "t={threads}");
            assert_eq!(spmm_tiled_with(&x, &c, 8, &p), spmm_tiled(&x, &c, 8), "t={threads}");
        }
    }

    #[test]
    fn derived_batch_tiling_matches_fixed_and_rowmajor() {
        // Narrow batches under wide tiles: the policy-derived batch step
        // (`tile_rows`) must change nothing bitwise — tiled stays exact
        // vs. row-major (the pre-change fixed tiling equalled row-major
        // by the same argument, so this transitively pins old == new).
        let mut rng = Rng::seed_from_u64(7);
        for rows in [1usize, 3, 5, 13] {
            let x = Matrix::randn(rows, 32, 1.0, &mut rng);
            let w = Matrix::randn(29, 32, 1.0, &mut rng);
            let mask = random_row_mask(29, 32, NmScheme::TWO_FOUR, &mut rng);
            let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
            let want = spmm_rowmajor(&x, &c);
            for tile in [8usize, 64] {
                assert_eq!(spmm_tiled(&x, &c, tile), want, "serial rows={rows} tile={tile}");
                for threads in [2usize, 4] {
                    let p =
                        ParallelPolicy { threads, min_rows_per_task: 1,
                                         partition: PartitionStrategy::Auto };
                    assert_eq!(spmm_tiled_with(&x, &c, tile, &p), want,
                               "rows={rows} tile={tile} t={threads}");
                }
            }
        }
    }

    #[test]
    fn tile_rows_tracks_worker_count() {
        let p = ParallelPolicy { threads: 4, min_rows_per_task: 1,
                                 partition: PartitionStrategy::Auto };
        // 13 rows / 4 tasks → ceil = 4; capped by the requested tile.
        assert_eq!(p.tile_rows(13, 64), 4);
        assert_eq!(p.tile_rows(13, 2), 2);
        assert_eq!(p.tile_rows(1, 64), 1);
        // Serial policy: one task owns all rows, tile cap applies.
        assert_eq!(ParallelPolicy::serial().tile_rows(100, 16), 16);
    }

    #[test]
    fn prepacked_matches_compressed_bitwise_all_partitions() {
        let mut rng = Rng::seed_from_u64(8);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let x = Matrix::randn(5, 5 * m, 1.0, &mut rng);
            let w = Matrix::randn(37, 5 * m, 1.0, &mut rng);
            let mask = random_row_mask(37, 5 * m, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            let p = crate::sparsity::PrepackedNm::prepack(&c);
            for level in [SimdLevel::Scalar, SimdLevel::Avx2] {
                let want = spmm_rowmajor_with_at(level, &x, &c, &ParallelPolicy::serial());
                for threads in [1usize, 4] {
                    for strategy in [PartitionStrategy::Auto, PartitionStrategy::Rows,
                                     PartitionStrategy::Cols]
                    {
                        let pol = ParallelPolicy { threads, min_rows_per_task: 1,
                                                   partition: strategy };
                        assert_eq!(spmm_prepacked_with_at(level, &x, &p, &pol), want,
                                   "{s} {level} t={threads} {strategy:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn byte_decode_matches_scalar_decode() {
        let mut rng = Rng::seed_from_u64(4);
        for cols in [8usize, 16, 20, 64] {
            // 20 cols ⇒ 5 groups: exercises the odd-group tail byte.
            let s = NmScheme::TWO_FOUR;
            let x = Matrix::randn(1, cols, 1.0, &mut rng);
            let w = Matrix::randn(9, cols, 1.0, &mut rng);
            let mask = random_row_mask(9, cols, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            let kc = c.kcols();
            let rmb = c.row_meta_bytes();
            for o in 0..c.rows {
                let vals = &c.values[o * kc..(o + 1) * kc];
                let meta = &c.meta[o * rmb..(o + 1) * rmb];
                // Pin at forced Scalar: the LUT whole-byte decode must be
                // bit-identical to the per-offset reference.  (At Avx2 the
                // FMA gather-dot is tolerance-pinned in simd_parity.)
                let fast = sparse_dot_at(SimdLevel::Scalar, x.row(0), vals, meta, s.n, s.m,
                                         s.offset_bits());
                let scalar = sparse_dot_scalar(x.row(0), vals, meta, s.n, s.m, s.offset_bits());
                assert_eq!(fast.to_bits(), scalar.to_bits(), "cols={cols} row={o}");
            }
        }
    }
}
