//! Structured SpMM over the compressed N:M layout: `Y = X · Wᵀ` with `W`
//! stored as (values, indices) — the computational core of SLoPe's FWD and
//! BWD-2.
//!
//! The N:M structure is what makes this fast: within a group of M dense
//! columns the kernel touches exactly N values with *known-monotone*
//! indices, so the inner loop is a short gather-multiply-accumulate with
//! perfect value locality — the CPU analogue of what sparse tensor cores
//! do with the 2:4 metadata.  Compared to the dense `gemm_nt`, it performs
//! `N/M` of the multiply-adds and streams `N/M` of the weight bytes.

use crate::sparsity::CompressedNm;
use crate::tensor::Matrix;

/// Execution strategy for SpMM (the §2.4 tiling ablation toggle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpmmAlgo {
    /// Straight row-major traversal.
    RowMajor,
    /// Square output tiles of the given edge (paper's upsample tiling).
    Tiled { tile: usize },
}

/// `Y[b, o] = Σ_k X[b, idx[o,k]] · vals[o,k]` — row-major traversal.
///
/// §Perf iteration (EXPERIMENTS.md §Perf/L3): gathers don't auto-vectorize,
/// so the kernel processes FOUR weight rows per pass — the four accumulator
/// chains give the out-of-order core independent gather streams (ILP) and
/// reuse the cached x row.  Measured ~1.3–1.5× over the 1-row loop.
pub fn spmm_rowmajor(x: &Matrix, w: &CompressedNm) -> Matrix {
    assert_eq!(x.cols, w.cols, "spmm: x cols must equal dense weight cols");
    let kc = w.kcols();
    let mut y = Matrix::zeros(x.rows, w.rows);
    let quads = w.rows / 4 * 4;
    for b in 0..x.rows {
        let xrow = x.row(b);
        let yrow = y.row_mut(b);
        let mut o = 0;
        while o < quads {
            let base = o * kc;
            let v = &w.values[base..base + 4 * kc];
            let ix = &w.indices[base..base + 4 * kc];
            let mut acc = [0.0f32; 4];
            for k in 0..kc {
                acc[0] += xrow[ix[k] as usize] * v[k];
                acc[1] += xrow[ix[kc + k] as usize] * v[kc + k];
                acc[2] += xrow[ix[2 * kc + k] as usize] * v[2 * kc + k];
                acc[3] += xrow[ix[3 * kc + k] as usize] * v[3 * kc + k];
            }
            yrow[o..o + 4].copy_from_slice(&acc);
            o += 4;
        }
        for o in quads..w.rows {
            let vals = &w.values[o * kc..(o + 1) * kc];
            let idxs = &w.indices[o * kc..(o + 1) * kc];
            yrow[o] = sparse_dot(xrow, vals, idxs);
        }
    }
    y
}

/// Square-tiled traversal (paper §2.4 / Appendix E): process `tile × tile`
/// output blocks so the active slice of `X` stays cache-resident while a
/// block of weight rows streams through.  This is the CPU analogue of
/// splitting the upsample weight into square sub-matrices for cuSPARSELt.
pub fn spmm_tiled(x: &Matrix, w: &CompressedNm, tile: usize) -> Matrix {
    assert_eq!(x.cols, w.cols);
    assert!(tile > 0);
    let kc = w.kcols();
    let mut y = Matrix::zeros(x.rows, w.rows);
    for bt in (0..x.rows).step_by(tile) {
        let bend = (bt + tile).min(x.rows);
        for ot in (0..w.rows).step_by(tile) {
            let oend = (ot + tile).min(w.rows);
            for b in bt..bend {
                let xrow = x.row(b);
                let yrow = y.row_mut(b);
                for o in ot..oend {
                    let vals = &w.values[o * kc..(o + 1) * kc];
                    let idxs = &w.indices[o * kc..(o + 1) * kc];
                    yrow[o] = sparse_dot(xrow, vals, idxs);
                }
            }
        }
    }
    y
}

/// Gather-dot over one compressed weight row.  4-wide unrolled: for 2:4
/// this is two groups per iteration; the index loads are u16 (half the
/// metadata traffic of u32 — the Eq. 7 bit-packing spirit).
#[inline]
fn sparse_dot(xrow: &[f32], vals: &[f32], idxs: &[u16]) -> f32 {
    let kc = vals.len();
    let mut acc = [0.0f32; 4];
    let chunks = kc / 4;
    for c in 0..chunks {
        let o = c * 4;
        for l in 0..4 {
            // SAFETY-free fast path: indices are validated < cols at
            // compress time; use get_unchecked-equivalent via debug assert.
            debug_assert!((idxs[o + l] as usize) < xrow.len());
            acc[l] += xrow[idxs[o + l] as usize] * vals[o + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 4..kc {
        s += xrow[idxs[i] as usize] * vals[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::gemm_nt;
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    #[test]
    fn spmm_matches_dense_on_masked_weight() {
        let mut rng = Rng::seed_from_u64(0);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let x = Matrix::randn(8, 8 * m, 1.0, &mut rng);
            let w = Matrix::randn(16, 8 * m, 1.0, &mut rng);
            let mask = random_row_mask(16, 8 * m, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            let want = gemm_nt(&x, &mask.apply(&w));
            assert!(spmm_rowmajor(&x, &c).max_abs_diff(&want) < 1e-4, "{s}");
        }
    }

    #[test]
    fn tiled_matches_rowmajor_ragged_tiles() {
        let mut rng = Rng::seed_from_u64(1);
        let x = Matrix::randn(13, 32, 1.0, &mut rng); // non-multiple rows
        let w = Matrix::randn(29, 32, 1.0, &mut rng); // non-multiple outs
        let mask = random_row_mask(29, 32, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let a = spmm_rowmajor(&x, &c);
        for tile in [1, 3, 7, 16, 64] {
            assert!(spmm_tiled(&x, &c, tile).max_abs_diff(&a) < 1e-4, "tile {tile}");
        }
    }
}
