//! The parallel execution engine for the kernel backend: row-range work
//! partitioning over std scoped threads — no external dependencies.
//!
//! Every kernel in this backend writes a row-major output whose rows are
//! independent (GEMM output rows, SpMM batch rows), so the engine's one
//! primitive is [`parallel_over_rows`]: split the output buffer into
//! contiguous row ranges, hand each range to a worker, and run the *same*
//! per-row loop body the serial kernel runs.  Because the partition never
//! changes the per-row reduction order, results are **bit-identical** to
//! the serial kernel at any thread count — the property the
//! `parallel_and_packed` test suite pins.
//!
//! [`ParallelPolicy`] is the configuration handle that persists across
//! kernel calls (it lives on [`crate::backend::SparseBackend`] and
//! [`crate::config::RunConfig`]): worker count plus a fork-granularity
//! floor so tiny matrices never pay thread-spawn latency.  Workers are
//! joined at region end by `std::thread::scope`, which is what lets them
//! borrow the operands directly instead of copying into `'static` jobs.

use std::ops::Range;

/// Parallelism configuration for the kernel engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelPolicy {
    /// Worker count; `0` = auto-detect from `available_parallelism`.
    pub threads: usize,
    /// Minimum output rows per task — below `threads × min_rows_per_task`
    /// rows the kernel runs serially (spawn cost would dominate).
    pub min_rows_per_task: usize,
}

impl ParallelPolicy {
    /// Single-threaded execution (the seed kernels' behavior).
    pub const fn serial() -> Self {
        Self { threads: 1, min_rows_per_task: 8 }
    }

    /// Use every available hardware thread.
    pub const fn auto() -> Self {
        Self { threads: 0, min_rows_per_task: 8 }
    }

    /// Fixed worker count (`0` = auto).
    pub const fn with_threads(threads: usize) -> Self {
        Self { threads, min_rows_per_task: 8 }
    }

    /// Policy for kernels over matrices of the given row width (`d_model`
    /// / `d_in`-sized): the fork floor scales with width so a task always
    /// carries enough arithmetic to amortize spawn latency, while tiny
    /// debug shapes stay effectively serial.  Used by the CLI (manifest
    /// `d_model`), the shape zoo, and the kernel benches.
    pub fn for_width(threads: usize, width: usize) -> Self {
        Self { threads, min_rows_per_task: (width / 256).clamp(4, 64) }
    }

    /// Resolved worker count (auto-detects when `threads == 0`).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// How many tasks to fork for an output with `rows` rows.
    pub fn tasks_for(&self, rows: usize) -> usize {
        let cap = rows / self.min_rows_per_task.max(1);
        self.effective_threads().min(cap.max(1)).max(1)
    }
}

impl Default for ParallelPolicy {
    /// Serial by default: callers opt into parallelism explicitly, so the
    /// pre-engine call sites keep their exact behavior.
    fn default() -> Self {
        Self::serial()
    }
}

/// Partition `data` (a `rows × row_len` row-major buffer) into contiguous
/// row ranges and run `body(range, chunk)` on each — workers on scoped
/// threads, the final range on the calling thread.  `body` must compute
/// rows independently; under that contract the result is bit-identical to
/// `body(0..rows, data)` at any thread count.
pub fn parallel_over_rows<F>(policy: &ParallelPolicy, data: &mut [f32], row_len: usize, body: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    debug_assert_eq!(rows * row_len, data.len(), "buffer must be rows × row_len");
    let tasks = policy.tasks_for(rows);
    if tasks <= 1 || row_len == 0 {
        body(0..rows, data);
        return;
    }
    std::thread::scope(|scope| {
        let body = &body;
        let mut rest: &mut [f32] = data;
        let mut start = 0usize;
        for t in 0..tasks - 1 {
            // Even partition: range t covers rows [rows·t/tasks, rows·(t+1)/tasks).
            let end = rows * (t + 1) / tasks;
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((end - start) * row_len);
            rest = tail;
            let range = start..end;
            scope.spawn(move || body(range, chunk));
            start = end;
        }
        body(start..rows, rest);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_policy_never_forks() {
        assert_eq!(ParallelPolicy::serial().tasks_for(1 << 20), 1);
    }

    #[test]
    fn tasks_respect_granularity_floor() {
        let p = ParallelPolicy { threads: 8, min_rows_per_task: 16 };
        assert_eq!(p.tasks_for(15), 1); // too small to fork
        assert_eq!(p.tasks_for(64), 4); // 64/16 caps below thread count
        assert_eq!(p.tasks_for(1024), 8); // thread count caps
    }

    #[test]
    fn auto_detects_at_least_one_thread() {
        assert!(ParallelPolicy::auto().effective_threads() >= 1);
    }

    #[test]
    fn for_width_scales_fork_floor() {
        assert_eq!(ParallelPolicy::for_width(4, 128).min_rows_per_task, 4); // floor
        assert_eq!(ParallelPolicy::for_width(4, 2048).min_rows_per_task, 8);
        assert_eq!(ParallelPolicy::for_width(4, 1 << 20).min_rows_per_task, 64); // cap
        assert_eq!(ParallelPolicy::for_width(4, 512).threads, 4);
    }

    #[test]
    fn partition_covers_every_row_exactly_once() {
        for threads in [1usize, 2, 3, 4, 7] {
            for rows in [1usize, 2, 7, 29, 64] {
                let row_len = 3;
                let mut data = vec![0.0f32; rows * row_len];
                let p = ParallelPolicy { threads, min_rows_per_task: 1 };
                parallel_over_rows(&p, &mut data, row_len, |range, chunk| {
                    assert_eq!(chunk.len(), range.len() * row_len);
                    for (local, r) in range.clone().enumerate() {
                        for c in 0..row_len {
                            chunk[local * row_len + c] += (r * row_len + c) as f32 + 1.0;
                        }
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, i as f32 + 1.0, "threads={threads} rows={rows} i={i}");
                }
            }
        }
    }

    #[test]
    fn zero_rows_is_a_noop() {
        let p = ParallelPolicy::with_threads(4);
        let mut empty: Vec<f32> = vec![];
        parallel_over_rows(&p, &mut empty, 8, |range, chunk| {
            assert!(range.is_empty() && chunk.is_empty());
        });
    }
}
