//! The parallel execution engine for the kernel backend: a **persistent
//! park/unpark worker pool** plus deterministic work partitioning — no
//! external dependencies.
//!
//! # Engine shape
//!
//! Workers are spawned **once** (lazily, on the first parallel region) and
//! then parked on a `Condvar`; every subsequent region is a wake → claim →
//! park cycle with no thread spawning at all.  The seed engine spawned
//! scoped threads per region (~10–50 µs per spawn), which was noise for
//! large shapes but capped scaling for the sub-100 µs kernels the serving
//! path runs; the persistent pool pushes the parallel crossover down to
//! where [`ParallelPolicy::min_rows_per_task`] puts it.  The test suite
//! pins the reuse property via [`spawned_thread_count`]: ≥ 1000 parallel
//! regions must not spawn a single new thread after warmup.
//!
//! # Determinism contract
//!
//! A region is a fixed set of `tasks` index-addressed work items whose
//! *partition* is a pure function of (shape, policy) — never of worker
//! count, claim order, or timing.  Workers claim task indices dynamically
//! from an atomic counter, but since every task computes the same output
//! range it would compute serially, results are **bit-identical** to the
//! serial kernel at any thread count — the property the
//! `parallel_and_packed` and `serve_and_pool` test suites pin.  The
//! partition is also independent of the kernels' [`crate::backend::simd`]
//! dispatch level: column stripes stay quad-aligned and tasks stay pure
//! functions of (shape, policy), so the across-thread bitwise contract
//! holds at every `SimdLevel` (pinned in `tests/simd_parity.rs`).
//!
//! # Partitioning strategies
//!
//! [`parallel_over_rows`] splits a row-major output into contiguous **row
//! ranges** (GEMM output rows, SpMM batch rows) — the right split when the
//! output has enough rows to saturate the pool.  For the serving-critical
//! `batch = 1` forward a row split cannot parallelize at all, so the
//! kernels can also split **output columns** (weight rows) into per-task
//! stripes via [`parallel_over_col_stripes`] + [`StripedOut`]: every task
//! writes a disjoint column stripe of every output row.
//! [`PartitionStrategy`] on [`ParallelPolicy`] selects Rows / Cols /
//! Auto (pick from shape); [`ParallelPolicy::resolve`] is the single
//! decision point the kernels share.
//!
//! [`ParallelPolicy`] persists across kernel calls (it lives on
//! [`crate::backend::SparseBackend`] and [`crate::config::RunConfig`]);
//! the pool itself is process-global and policy-independent — a policy
//! only decides how many tasks a region forks, the pool executes them on
//! however many workers the hardware has.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// How a kernel splits its output across pool tasks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Pick per call from the output shape: row split when the output has
    /// enough rows to occupy every worker, else column split (the
    /// `batch = 1` serving case).
    #[default]
    Auto,
    /// Always split output rows (the seed engine's only strategy).
    Rows,
    /// Always split output columns (weight rows) into per-task stripes.
    Cols,
}

/// A resolved partition decision for one kernel call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    /// Run the serial kernel body on the calling thread.
    Serial,
    /// Split output rows into this many contiguous ranges.
    Rows(usize),
    /// Split output columns into this many contiguous stripes.
    Cols(usize),
}

/// Minimum output columns per stripe under a column split — below this a
/// stripe carries too little arithmetic to amortize a worker wakeup.
const MIN_COLS_PER_STRIPE: usize = 8;

/// Parallelism configuration for the kernel engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelPolicy {
    /// Worker count; `0` = auto-detect from `available_parallelism`.
    pub threads: usize,
    /// Minimum output rows per task — below `threads × min_rows_per_task`
    /// rows a row split runs serially (wakeup cost would dominate).
    pub min_rows_per_task: usize,
    /// Row/column split selection (Auto picks from the output shape).
    pub partition: PartitionStrategy,
}

impl ParallelPolicy {
    /// Single-threaded execution (the seed kernels' behavior).
    pub const fn serial() -> Self {
        Self { threads: 1, min_rows_per_task: 8, partition: PartitionStrategy::Auto }
    }

    /// Use every available hardware thread.
    pub const fn auto() -> Self {
        Self { threads: 0, min_rows_per_task: 8, partition: PartitionStrategy::Auto }
    }

    /// Fixed worker count (`0` = auto).
    pub const fn with_threads(threads: usize) -> Self {
        Self { threads, min_rows_per_task: 8, partition: PartitionStrategy::Auto }
    }

    /// Same policy with an explicit partition strategy.
    pub const fn with_partition(mut self, partition: PartitionStrategy) -> Self {
        self.partition = partition;
        self
    }

    /// Policy for kernels over matrices of the given row width (`d_model`
    /// / `d_in`-sized): the fork floor scales with width so a task always
    /// carries enough arithmetic to amortize wakeup latency, while tiny
    /// debug shapes stay effectively serial.  Used by the CLI (manifest
    /// `d_model`), the shape zoo, and the kernel benches.
    pub fn for_width(threads: usize, width: usize) -> Self {
        Self {
            threads,
            min_rows_per_task: (width / 256).clamp(4, 64),
            partition: PartitionStrategy::Auto,
        }
    }

    /// Resolved worker count (auto-detects when `threads == 0`).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// How many tasks to fork for an output with `rows` rows.
    pub fn tasks_for(&self, rows: usize) -> usize {
        let cap = rows / self.min_rows_per_task.max(1);
        self.effective_threads().min(cap.max(1)).max(1)
    }

    /// How many column stripes to fork for an output `cols` wide.
    pub fn col_tasks_for(&self, cols: usize) -> usize {
        let cap = cols / MIN_COLS_PER_STRIPE;
        self.effective_threads().min(cap.max(1)).max(1)
    }

    /// Batch-row tile height for a tiled traversal over `rows` rows,
    /// capped at the caller's requested `tile` edge.  Matching the tile
    /// height to the resolved task count (`ceil(rows / tasks)`) keeps a
    /// narrow batch from being swallowed whole by one oversized tile —
    /// with 13 rows, 4 workers, and a 64-edge tile the old fixed step
    /// left the traversal's blocking useless and (with coarse
    /// `min_rows_per_task`) work lumped onto few workers; the derived
    /// height tracks how the pool will actually split the rows.  Purely
    /// a traversal-order knob: every output element is an independent
    /// reduction, so any tile height yields bit-identical results
    /// (pinned against the fixed tiling in the spmm tests).
    pub fn tile_rows(&self, rows: usize, tile: usize) -> usize {
        let tasks = self.tasks_for(rows.max(1)).max(1);
        rows.max(1).div_ceil(tasks).clamp(1, tile.max(1))
    }

    /// Resolve the partition for an `out_rows × out_cols` kernel output.
    ///
    /// `Auto` prefers the row split (contiguous writes) whenever it can
    /// occupy every worker or beats the column split's task count;
    /// otherwise — the small-batch serving shape — it stripes columns.
    pub fn resolve(&self, out_rows: usize, out_cols: usize) -> Partition {
        let row_tasks = self.tasks_for(out_rows);
        let col_tasks = self.col_tasks_for(out_cols);
        let chosen = match self.partition {
            PartitionStrategy::Rows => Partition::Rows(row_tasks),
            PartitionStrategy::Cols => Partition::Cols(col_tasks),
            PartitionStrategy::Auto => {
                if row_tasks >= self.effective_threads() || row_tasks >= col_tasks {
                    Partition::Rows(row_tasks)
                } else {
                    Partition::Cols(col_tasks)
                }
            }
        };
        match chosen {
            Partition::Rows(t) | Partition::Cols(t) if t <= 1 => Partition::Serial,
            other => other,
        }
    }
}

impl Default for ParallelPolicy {
    /// Serial by default: callers opt into parallelism explicitly, so the
    /// pre-engine call sites keep their exact behavior.
    fn default() -> Self {
        Self::serial()
    }
}

// ---- the persistent worker pool ---------------------------------------

/// Monotonic count of OS threads ever spawned by pool instances — the
/// test hook that pins "≥ 1000 regions, zero new spawns after warmup".
static SPAWNED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Total OS threads spawned by all [`WorkerPool`]s since process start.
pub fn spawned_thread_count() -> usize {
    SPAWNED_THREADS.load(Ordering::SeqCst)
}

thread_local! {
    /// Set while this thread executes inside a pool task (worker threads
    /// permanently; the submitting thread during its own participation).
    /// A nested region then runs inline instead of deadlocking on the
    /// submit lock.
    static IN_POOL_TASK: Cell<bool> = const { Cell::new(false) };
}

/// One parallel region: a borrowed task closure plus the claim counter.
/// Lives on the submitting thread's stack; workers access it through a
/// raw pointer that is only valid because [`WorkerPool::run`] does not
/// return (or unwind) before every helper has parked again.
struct Job {
    /// Really `&'region (dyn Fn(usize) + Sync)` — the lifetime is erased
    /// to `'static` at submit because the epoch barrier guarantees the
    /// region outlives every call through it.
    task: &'static (dyn Fn(usize) + Sync),
    /// Next unclaimed task index.
    next: AtomicUsize,
    tasks: usize,
}

/// Raw job pointer, shared with workers under the control mutex.
#[derive(Clone, Copy)]
struct JobPtr(*const Job);
// SAFETY: the pointee outlives every dereference — `run` blocks until all
// helpers of the epoch have finished before the `Job` leaves scope.
unsafe impl Send for JobPtr {}

struct Ctl {
    /// Region generation; bumping it (under the mutex) publishes a job.
    epoch: u64,
    job: Option<JobPtr>,
    /// Workers enlisted for the current epoch (`idx < helpers`).
    helpers: usize,
    /// Enlisted workers that have not yet finished the current epoch.
    active: usize,
    shutdown: bool,
}

struct Shared {
    ctl: Mutex<Ctl>,
    /// Parked workers wait here for an epoch bump.
    work: Condvar,
    /// The submitter waits here for `active == 0`.
    done: Condvar,
    /// A worker task panicked this epoch (re-raised by the submitter).
    panicked: AtomicBool,
}

/// A persistent set of parked worker threads executing index-addressed
/// task regions.  One process-global instance ([`WorkerPool::global`])
/// serves every kernel call; dedicated instances exist for tests.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: usize,
    /// Serializes whole regions: two threads submitting concurrently get
    /// queued, never interleaved epochs.
    submit: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `workers` parked helper threads (the submitting
    /// thread always participates too, so total parallelism is
    /// `workers + 1`).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            ctl: Mutex::new(Ctl {
                epoch: 0,
                job: None,
                helpers: 0,
                active: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|idx| {
                SPAWNED_THREADS.fetch_add(1, Ordering::SeqCst);
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("slope-pool-{idx}"))
                    .spawn(move || worker_loop(sh, idx))
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, workers, submit: Mutex::new(()), handles }
    }

    /// The process-global pool, spawned on first use with
    /// `available_parallelism − 1` helpers (the caller is the +1).
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            WorkerPool::new(hw.saturating_sub(1))
        })
    }

    /// Parked helper threads in this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute `task(0..tasks)` across the pool; returns when every task
    /// has finished.  Which worker runs which index is nondeterministic,
    /// but each index runs exactly once, so any index-deterministic task
    /// set yields deterministic results.  Nested calls from inside a task
    /// run inline (serially) on the calling thread.
    pub fn run(&self, tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if tasks <= 1 || self.workers == 0 || IN_POOL_TASK.with(|f| f.get()) {
            for t in 0..tasks {
                task(t);
            }
            return;
        }
        let region = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        // SAFETY: lifetime erasure only — `run` does not return (even on
        // panic) until every helper has finished with `job`, so the
        // closure outlives all uses of this "'static" reference.
        let task_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(task) };
        let job = Job { task: task_static, next: AtomicUsize::new(0), tasks };
        {
            let mut ctl = self.shared.ctl.lock().unwrap_or_else(|e| e.into_inner());
            ctl.epoch = ctl.epoch.wrapping_add(1);
            ctl.helpers = self.workers.min(tasks - 1);
            ctl.active = ctl.helpers;
            ctl.job = Some(JobPtr(&job));
            self.shared.work.notify_all();
        }
        // The submitter claims tasks like any worker.  Panics are deferred
        // until every helper has parked — unwinding past `job` while a
        // worker still holds its address would be unsound.
        IN_POOL_TASK.with(|f| f.set(true));
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let t = job.next.fetch_add(1, Ordering::SeqCst);
            if t >= tasks {
                break;
            }
            task(t);
        }));
        IN_POOL_TASK.with(|f| f.set(false));
        {
            let mut ctl = self.shared.ctl.lock().unwrap_or_else(|e| e.into_inner());
            while ctl.active > 0 {
                ctl = self.shared.done.wait(ctl).unwrap_or_else(|e| e.into_inner());
            }
            ctl.job = None;
        }
        drop(region);
        // Consume the worker-panic flag BEFORE re-raising a caller panic:
        // leaving it set would make the next unrelated region on this pool
        // panic spuriously.
        let worker_panicked = self.shared.panicked.swap(false, Ordering::SeqCst);
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("worker pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut ctl = self.shared.ctl.lock().unwrap_or_else(|e| e.into_inner());
            ctl.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, idx: usize) {
    IN_POOL_TASK.with(|f| f.set(true));
    let mut last_epoch = 0u64;
    loop {
        let (job, participate);
        {
            let mut ctl = shared.ctl.lock().unwrap_or_else(|e| e.into_inner());
            while ctl.epoch == last_epoch && !ctl.shutdown {
                ctl = shared.work.wait(ctl).unwrap_or_else(|e| e.into_inner());
            }
            if ctl.shutdown {
                return;
            }
            last_epoch = ctl.epoch;
            participate = idx < ctl.helpers;
            job = ctl.job;
        }
        let Some(JobPtr(job)) = job else { continue };
        if !participate {
            continue;
        }
        // SAFETY: the submitter of this epoch is blocked in `run` until we
        // decrement `active` below, so the Job (and the closure it points
        // to) is alive for the whole claim loop.
        let job = unsafe { &*job };
        let task = job.task;
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let t = job.next.fetch_add(1, Ordering::SeqCst);
            if t >= job.tasks {
                break;
            }
            task(t);
        }));
        if r.is_err() {
            shared.panicked.store(true, Ordering::SeqCst);
        }
        let mut ctl = shared.ctl.lock().unwrap_or_else(|e| e.into_inner());
        ctl.active -= 1;
        if ctl.active == 0 {
            shared.done.notify_all();
        }
    }
}

// ---- partition primitives ---------------------------------------------

/// Mutable pointer shared read-only across tasks; each task derives its
/// own disjoint sub-slice from the task index.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: tasks only touch disjoint index ranges (enforced by the
// deterministic partition arithmetic below).
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Partition `data` (a `rows × row_len` row-major buffer) into contiguous
/// row ranges and run `body(range, chunk)` on each — ranges on persistent
/// pool workers plus the calling thread.  `body` must compute rows
/// independently; under that contract the result is bit-identical to
/// `body(0..rows, data)` at any thread count.
pub fn parallel_over_rows<F>(policy: &ParallelPolicy, data: &mut [f32], row_len: usize, body: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    debug_assert_eq!(rows * row_len, data.len(), "buffer must be rows × row_len");
    let tasks = policy.tasks_for(rows);
    if tasks <= 1 || row_len == 0 {
        body(0..rows, data);
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    let task_fn = move |t: usize| {
        // Even partition: task t covers rows [rows·t/tasks, rows·(t+1)/tasks)
        // — a pure function of (rows, tasks), independent of which worker
        // claims the index.
        let start = rows * t / tasks;
        let end = rows * (t + 1) / tasks;
        // SAFETY: row ranges of distinct tasks are disjoint and in-bounds,
        // and each task index is claimed exactly once.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(start * row_len), (end - start) * row_len)
        };
        body(start..end, chunk);
    };
    WorkerPool::global().run(tasks, &task_fn);
}

/// Split `0..cols` into `tasks` contiguous stripes and run `body(stripe)`
/// on the pool.  The body must only write output columns inside its
/// stripe (via [`StripedOut`]); stripes of distinct tasks are disjoint,
/// so the writes never alias.
///
/// Stripe boundaries are **quad-aligned**: the split is computed over
/// `ceil(cols / 4)` four-column quads, so every stripe except possibly
/// the last has a multiple-of-4 width.  The SpMM/GEMM cores process four
/// output columns (weight rows) per pass for ILP; with unaligned
/// boundaries every narrow stripe ended in a `< 4`-wide element-wise
/// tail, costing the narrow-stripe serving shapes their four-chain
/// gather parallelism.  Quad alignment confines the ragged tail to the
/// single final stripe.  Which columns land in which stripe is still a
/// pure function of `(cols, tasks)`, and each output element's value is
/// independent of the partition, so results stay bit-identical to serial.
pub fn parallel_over_col_stripes<F>(tasks: usize, cols: usize, body: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let quads = cols.div_ceil(4);
    let tasks = tasks.min(quads).max(1);
    let task_fn = move |t: usize| {
        let start = 4 * (quads * t / tasks);
        let end = (4 * (quads * (t + 1) / tasks)).min(cols);
        body(start..end);
    };
    WorkerPool::global().run(tasks, &task_fn);
}

/// Column-striped mutable view of a `rows × row_len` row-major buffer for
/// kernels whose tasks write disjoint *column* stripes of every row
/// (the `batch = 1` partition, where row chunks cannot be handed out).
pub struct StripedOut {
    ptr: *mut f32,
    rows: usize,
    row_len: usize,
}

// SAFETY: concurrent users hold disjoint column stripes (the
// `parallel_over_col_stripes` contract), so derived slices never overlap.
unsafe impl Send for StripedOut {}
unsafe impl Sync for StripedOut {}

impl StripedOut {
    pub fn new(data: &mut [f32], row_len: usize) -> Self {
        let rows = if row_len == 0 { 0 } else { data.len() / row_len };
        debug_assert_eq!(rows * row_len, data.len());
        Self { ptr: data.as_mut_ptr(), rows, row_len }
    }

    /// Mutable slice of `stripe` within row `row`.
    ///
    /// # Safety
    /// Callers must hold disjoint `(row, stripe)` regions across threads:
    /// under `parallel_over_col_stripes` each task passes only its own
    /// stripe, which is disjoint from every other task's.
    #[inline]
    pub unsafe fn row_stripe(&self, row: usize, stripe: Range<usize>) -> &mut [f32] {
        debug_assert!(row < self.rows && stripe.end <= self.row_len);
        std::slice::from_raw_parts_mut(
            self.ptr.add(row * self.row_len + stripe.start),
            stripe.len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that construct pools or read the global spawn
    /// counter — libtest runs tests concurrently in one process, and a
    /// dedicated pool spawning mid-measurement would trip the counter.
    static POOL_TEST_LOCK: Mutex<()> = Mutex::new(());

    fn pool_test_guard() -> std::sync::MutexGuard<'static, ()> {
        POOL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn serial_policy_never_forks() {
        assert_eq!(ParallelPolicy::serial().tasks_for(1 << 20), 1);
    }

    #[test]
    fn tasks_respect_granularity_floor() {
        let p = ParallelPolicy { threads: 8, min_rows_per_task: 16, ..ParallelPolicy::serial() };
        assert_eq!(p.tasks_for(15), 1); // too small to fork
        assert_eq!(p.tasks_for(64), 4); // 64/16 caps below thread count
        assert_eq!(p.tasks_for(1024), 8); // thread count caps
    }

    #[test]
    fn auto_detects_at_least_one_thread() {
        assert!(ParallelPolicy::auto().effective_threads() >= 1);
    }

    #[test]
    fn for_width_scales_fork_floor() {
        assert_eq!(ParallelPolicy::for_width(4, 128).min_rows_per_task, 4); // floor
        assert_eq!(ParallelPolicy::for_width(4, 2048).min_rows_per_task, 8);
        assert_eq!(ParallelPolicy::for_width(4, 1 << 20).min_rows_per_task, 64); // cap
        assert_eq!(ParallelPolicy::for_width(4, 512).threads, 4);
    }

    #[test]
    fn resolve_prefers_rows_when_batch_saturates() {
        let p = ParallelPolicy { threads: 4, min_rows_per_task: 1, ..ParallelPolicy::serial() };
        assert_eq!(p.resolve(64, 64), Partition::Rows(4));
        // batch=1 cannot row-split: Auto stripes columns.
        assert_eq!(p.resolve(1, 64), Partition::Cols(4));
        // Tiny outputs stay serial either way.
        assert_eq!(p.resolve(1, 4), Partition::Serial);
        // Explicit strategies are honored.
        assert_eq!(p.with_partition(PartitionStrategy::Rows).resolve(1, 64), Partition::Serial);
        assert_eq!(p.with_partition(PartitionStrategy::Cols).resolve(64, 64), Partition::Cols(4));
    }

    #[test]
    fn partition_covers_every_row_exactly_once() {
        for threads in [1usize, 2, 3, 4, 7] {
            for rows in [1usize, 2, 7, 29, 64] {
                let row_len = 3;
                let mut data = vec![0.0f32; rows * row_len];
                let p = ParallelPolicy {
                    threads,
                    min_rows_per_task: 1,
                    ..ParallelPolicy::serial()
                };
                parallel_over_rows(&p, &mut data, row_len, |range, chunk| {
                    assert_eq!(chunk.len(), range.len() * row_len);
                    for (local, r) in range.clone().enumerate() {
                        for c in 0..row_len {
                            chunk[local * row_len + c] += (r * row_len + c) as f32 + 1.0;
                        }
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, i as f32 + 1.0, "threads={threads} rows={rows} i={i}");
                }
            }
        }
    }

    #[test]
    fn col_stripes_cover_every_column_exactly_once() {
        for tasks in [1usize, 2, 3, 5, 8] {
            for cols in [1usize, 7, 16, 33] {
                let rows = 3;
                let mut data = vec![0.0f32; rows * cols];
                let out = StripedOut::new(&mut data, cols);
                parallel_over_col_stripes(tasks, cols, |stripe| {
                    for r in 0..rows {
                        let s = unsafe { out.row_stripe(r, stripe.clone()) };
                        for (local, c) in stripe.clone().enumerate() {
                            s[local] += (r * cols + c) as f32 + 1.0;
                        }
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, i as f32 + 1.0, "tasks={tasks} cols={cols} i={i}");
                }
            }
        }
    }

    #[test]
    fn col_stripes_are_quad_aligned_with_one_ragged_tail() {
        for tasks in [2usize, 3, 5, 8] {
            for cols in [9usize, 12, 23, 37, 64] {
                let bounds = Mutex::new(Vec::new());
                parallel_over_col_stripes(tasks, cols, |stripe| {
                    bounds.lock().unwrap().push((stripe.start, stripe.end));
                });
                let mut b = bounds.into_inner().unwrap();
                b.sort_unstable();
                assert_eq!(b.first().unwrap().0, 0, "tasks={tasks} cols={cols}");
                assert_eq!(b.last().unwrap().1, cols, "tasks={tasks} cols={cols}");
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "stripes must tile 0..cols contiguously");
                }
                for (i, (s, e)) in b.iter().enumerate() {
                    assert!(e > s, "no empty stripes (tasks={tasks} cols={cols})");
                    assert_eq!(s % 4, 0, "stripe starts are quad-aligned");
                    if i + 1 < b.len() {
                        assert_eq!((e - s) % 4, 0,
                                   "only the final stripe may carry a ragged quad tail");
                    }
                }
            }
        }
    }

    #[test]
    fn zero_rows_is_a_noop() {
        let p = ParallelPolicy::with_threads(4);
        let mut empty: Vec<f32> = vec![];
        parallel_over_rows(&p, &mut empty, 8, |range, chunk| {
            assert!(range.is_empty() && chunk.is_empty());
        });
    }

    #[test]
    fn dedicated_pool_runs_every_task_once() {
        let _g = pool_test_guard();
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.run(hits.len(), &|t| {
                hits[t].fetch_add(1, Ordering::SeqCst);
            });
        }
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 50, "task {t}");
        }
    }

    #[test]
    fn nested_regions_run_inline() {
        let _g = pool_test_guard();
        let pool = WorkerPool::new(2);
        let sum = AtomicUsize::new(0);
        pool.run(4, &|_| {
            // A nested region from inside a task must not deadlock.
            WorkerPool::global().run(3, &|u| {
                sum.fetch_add(u + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(sum.load(Ordering::SeqCst), 4 * (1 + 2 + 3));
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let _g = pool_test_guard();
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|t| {
                if t % 2 == 1 {
                    panic!("boom {t}");
                }
            });
        }));
        assert!(r.is_err(), "task panic must surface in run()");
        // The pool must still be usable afterwards.
        let ran = AtomicUsize::new(0);
        pool.run(8, &|_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn spawn_counter_is_flat_across_regions() {
        let _g = pool_test_guard();
        // Warm the global pool, then hammer it: no new threads may spawn.
        let p = ParallelPolicy { threads: 4, min_rows_per_task: 1, ..ParallelPolicy::serial() };
        let mut data = vec![0.0f32; 64 * 4];
        parallel_over_rows(&p, &mut data, 4, |_, chunk| {
            for v in chunk {
                *v += 1.0;
            }
        });
        let spawned = spawned_thread_count();
        for _ in 0..100 {
            parallel_over_rows(&p, &mut data, 4, |_, chunk| {
                for v in chunk {
                    *v += 1.0;
                }
            });
        }
        assert_eq!(spawned_thread_count(), spawned, "regions must reuse parked workers");
    }
}
