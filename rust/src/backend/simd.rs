//! Runtime-dispatched SIMD microkernels for the kernel engine.
//!
//! Every hot loop in [`crate::backend::gemm`] and [`crate::backend::spmm`]
//! routes through a [`SimdLevel`] chosen **once per process**:
//! `Avx2` (AVX2 + FMA, x86_64 only, detected via
//! `is_x86_feature_detected!`) or `Scalar` (the original safe-Rust
//! kernels, byte-for-byte unchanged — the pinned ground truth on every
//! architecture).  `SLOPE_SIMD=auto|avx2|scalar` overrides detection;
//! requesting `avx2` on hardware without it warns and falls back rather
//! than executing illegal instructions.
//!
//! # Determinism contract
//!
//! * **Within a level**: every output element is computed by the same
//!   microkernel in the same reduction order regardless of how the pool
//!   partitions the output (serial / row ranges / quad-aligned column
//!   stripes / tiles).  Results are therefore **bit-identical across
//!   thread counts and traversal orders**, exactly as before this layer
//!   existed — all pre-SIMD bitwise pins (parallel-vs-serial,
//!   tiled-vs-rowmajor, KV-decode-vs-recompute, crash-recovery resume
//!   byte-compares) hold at any fixed level.
//! * **Across levels**: the AVX2 kernels accumulate in vector lanes and
//!   contract multiply-adds through FMA, which reassociates the float
//!   reduction; `Avx2` and `Scalar` results agree to tight relative
//!   tolerance (pinned in `tests/simd_parity.rs`), and agree **bitwise**
//!   on inputs where no rounding occurs at all (small integers — also
//!   pinned, which checks the gather indexing end-to-end).
//!
//! # Microkernels
//!
//! * [`x86::dot`] — 4×8-lane FMA inner product (dense `gemm_nt` /
//!   `gemm_nt_acc`, attention, LoRA, BWD-1 staging);
//! * [`x86::axpy`] — 8-lane `y += a·x` row update (`gemm` / `gemm_tn`
//!   rank-1 inner loops, the BWD-1 `∇Yᵀ·X` saxpy form);
//! * [`x86::sparse_dot24`] — the 2:4 gather-dot: one metadata byte is
//!   decoded through the [`IDX24`] lane-permute LUT and its four kept
//!   values FMA against a 16-float window of `x` in two
//!   `vpermps`-gathered half-registers — eight multiply-adds per
//!   iteration where the scalar path does one.  This is the CPU analogue
//!   of the metadata decode sparse tensor cores do in hardware, and the
//!   same trick powers the row-compressed double-pruned transpose SpMM
//!   (Eq.-6 BWD-2) because that operand is just another `CompressedNm`.

use std::sync::OnceLock;

/// Instruction-set level the kernel engine dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable safe-Rust kernels — the pinned reference on every arch.
    Scalar,
    /// AVX2 + FMA microkernels (x86_64 only).
    Avx2,
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
        })
    }
}

/// Whether this process can execute the AVX2+FMA microkernels.
#[inline]
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn detect() -> SimdLevel {
    let want = std::env::var("SLOPE_SIMD").unwrap_or_default();
    match want.as_str() {
        "scalar" => SimdLevel::Scalar,
        "avx2" => {
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                eprintln!("[simd] SLOPE_SIMD=avx2 requested but AVX2+FMA unavailable; \
                           falling back to scalar");
                SimdLevel::Scalar
            }
        }
        "" | "auto" => {
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
        other => {
            eprintln!("[simd] unknown SLOPE_SIMD={other:?} (want auto|avx2|scalar); using auto");
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
    }
}

/// The process-wide dispatch level, detected once (first call) and cached.
pub fn simd_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(detect)
}

/// Clamp a requested level to what the hardware can actually run.  Every
/// `*_at` kernel entry point calls this, so passing `Avx2` on a machine
/// without it is safe (it silently runs scalar) rather than UB.
#[inline]
pub fn effective(level: SimdLevel) -> SimdLevel {
    match level {
        SimdLevel::Avx2 if !avx2_available() => SimdLevel::Scalar,
        l => l,
    }
}

/// AVX2+FMA microkernels.  Callers must hold `effective(Avx2) == Avx2`
/// (i.e. go through the dispatchers) before entering any of these.
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use std::arch::x86_64::*;

    /// Lane-permute LUT for one 2:4 metadata byte: entries 0/1 are the
    /// low-nibble group's intra-group offsets (window floats 0..4), and
    /// entries 2/3 the high-nibble group's offsets biased by 4 (window
    /// floats 4..8).  Loaded as a `__m256i` permute index whose upper
    /// four lanes are unused.
    const IDX24: [[u32; 8]; 256] = build_idx24();

    const fn build_idx24() -> [[u32; 8]; 256] {
        let mut t = [[0u32; 8]; 256];
        let mut b = 0usize;
        while b < 256 {
            t[b] = [
                (b & 3) as u32,
                ((b >> 2) & 3) as u32,
                4 + ((b >> 4) & 3) as u32,
                4 + ((b >> 6) & 3) as u32,
                0,
                0,
                0,
                0,
            ];
            b += 1;
        }
        t
    }

    /// Horizontal sum of a `__m256` in a fixed lane order (0..7), so the
    /// reduction is deterministic run-to-run.
    ///
    /// # Safety
    /// Requires AVX2 at runtime.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        // Pairwise within 128-bit halves, then across: a fixed tree that
        // does not depend on data, so results are deterministic.
        ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
    }

    /// FMA inner product over `k` elements: 4 independent 8-lane
    /// accumulator chains, an 8-wide cleanup loop, then a fixed-order
    /// horizontal reduction and a scalar `mul_add` tail.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; `a` and `b` must each hold at least
    /// `k` elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
        debug_assert!(a.len() >= k && b.len() >= k);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 32 <= k {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 16)),
                _mm256_loadu_ps(pb.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 24)),
                _mm256_loadu_ps(pb.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= k {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
        let mut s = hsum(acc);
        while i < k {
            s = (*pa.add(i)).mul_add(*pb.add(i), s);
            i += 1;
        }
        s
    }

    /// `y[..n] += a · x[..n]` — the rank-1-update row kernel for
    /// `gemm` / `gemm_tn`.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; `x` and `y` must each hold at least
    /// `n` elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32], n: usize) {
        debug_assert!(x.len() >= n && y.len() >= n);
        let av = _mm256_set1_ps(a);
        let (px, py) = (x.as_ptr(), y.as_mut_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let yv = _mm256_loadu_ps(py.add(i));
            _mm256_storeu_ps(py.add(i), _mm256_fmadd_ps(av, _mm256_loadu_ps(px.add(i)), yv));
            i += 8;
        }
        while i < n {
            *py.add(i) = a.mul_add(*px.add(i), *py.add(i));
            i += 1;
        }
    }

    /// 2:4 gather-dot over one compressed weight row: per metadata byte
    /// **pair** (four groups, eight kept values, a 16-float window of
    /// `x`), decode both bytes through [`IDX24`], `vpermps`-gather each
    /// byte's four operands from its 8-float half-window, combine the two
    /// half-registers, and FMA against the eight contiguous `vals` — then
    /// at most one whole trailing byte and one half-byte scalar tail.
    /// Two accumulator chains keep the gather streams independent.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime.  `vals.len()` (= kc) kept values and
    /// `ceil(kc/4)` metadata bytes must be present, and `xrow` must cover
    /// the dense columns (`≥ kc/4·8` floats for the full bytes it
    /// touches) — guaranteed by `CompressedNm`'s layout invariants.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sparse_dot24(xrow: &[f32], vals: &[f32], meta: &[u8]) -> f32 {
        let kc = vals.len();
        let pairs = kc / 4; // full metadata bytes (2 groups / 8 dense cols each)
        let px = xrow.as_ptr();
        let pv = vals.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut byte = 0;
        // Byte pairs: 16 dense columns / 8 kept values per iteration.
        while byte + 2 <= pairs {
            let b0 = *meta.get_unchecked(byte) as usize;
            let b1 = *meta.get_unchecked(byte + 1) as usize;
            let base = byte * 8;
            // Window for byte 0 (cols base..base+8) and byte 1 (+8..+16).
            let w0 = _mm256_loadu_ps(px.add(base));
            let w1 = _mm256_loadu_ps(px.add(base + 8));
            let g0 = _mm256_permutevar8x32_ps(
                w0,
                _mm256_loadu_si256(IDX24[b0].as_ptr() as *const __m256i),
            );
            let g1 = _mm256_permutevar8x32_ps(
                w1,
                _mm256_loadu_si256(IDX24[b1].as_ptr() as *const __m256i),
            );
            // Gathered operands live in each register's low 128 bits;
            // pack byte 1's four into the high half of byte 0's register.
            let gathered = _mm256_insertf128_ps::<1>(g0, _mm256_castps256_ps128(g1));
            let v = _mm256_loadu_ps(pv.add(byte * 4));
            if byte % 4 == 0 {
                acc0 = _mm256_fmadd_ps(gathered, v, acc0);
            } else {
                acc1 = _mm256_fmadd_ps(gathered, v, acc1);
            }
            byte += 2;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        let mut k = byte * 4;
        let mut base = byte * 8;
        // At most one full trailing byte (odd `pairs`), done scalar.
        if byte < pairs {
            let d = IDX24[*meta.get_unchecked(byte) as usize];
            s = (*px.add(base + d[0] as usize)).mul_add(*pv.add(k), s);
            s = (*px.add(base + d[1] as usize)).mul_add(*pv.add(k + 1), s);
            s = (*px.add(base + d[2] as usize)).mul_add(*pv.add(k + 2), s);
            s = (*px.add(base + d[3] as usize)).mul_add(*pv.add(k + 3), s);
            k += 4;
            base += 8;
        }
        // Odd group count: the final byte's low nibble holds one group.
        if k < kc {
            let d = IDX24[*meta.get_unchecked(pairs) as usize];
            s = (*px.add(base + d[0] as usize)).mul_add(*pv.add(k), s);
            s = (*px.add(base + d[1] as usize)).mul_add(*pv.add(k + 1), s);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_never_exceeds_hardware() {
        assert_eq!(effective(SimdLevel::Scalar), SimdLevel::Scalar);
        let e = effective(SimdLevel::Avx2);
        if avx2_available() {
            assert_eq!(e, SimdLevel::Avx2);
        } else {
            assert_eq!(e, SimdLevel::Scalar);
        }
    }

    #[test]
    fn level_display_names() {
        assert_eq!(SimdLevel::Scalar.to_string(), "scalar");
        assert_eq!(SimdLevel::Avx2.to_string(), "avx2");
    }
}
