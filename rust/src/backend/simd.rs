//! Runtime-dispatched SIMD microkernels for the kernel engine.
//!
//! Every hot loop in [`crate::backend::gemm`] and [`crate::backend::spmm`]
//! routes through a [`SimdLevel`] chosen **once per process**:
//! `Avx2` (AVX2 + FMA, x86_64 only, detected via
//! `is_x86_feature_detected!`) or `Scalar` (the original safe-Rust
//! kernels, byte-for-byte unchanged — the pinned ground truth on every
//! architecture).  `SLOPE_SIMD=auto|avx2|scalar` overrides detection;
//! requesting `avx2` on hardware without it warns and falls back rather
//! than executing illegal instructions.
//!
//! # Determinism contract
//!
//! * **Within a level**: every output element is computed by the same
//!   microkernel in the same reduction order regardless of how the pool
//!   partitions the output (serial / row ranges / quad-aligned column
//!   stripes / tiles).  Results are therefore **bit-identical across
//!   thread counts and traversal orders**, exactly as before this layer
//!   existed — all pre-SIMD bitwise pins (parallel-vs-serial,
//!   tiled-vs-rowmajor, KV-decode-vs-recompute, crash-recovery resume
//!   byte-compares) hold at any fixed level.
//! * **Across levels**: the AVX2 kernels accumulate in vector lanes and
//!   contract multiply-adds through FMA, which reassociates the float
//!   reduction; `Avx2` and `Scalar` results agree to tight relative
//!   tolerance (pinned in `tests/simd_parity.rs`), and agree **bitwise**
//!   on inputs where no rounding occurs at all (small integers — also
//!   pinned, which checks the gather indexing end-to-end).
//!
//! # Microkernels
//!
//! * [`x86::dot`] — 4×8-lane FMA inner product (dense `gemm_nt` /
//!   `gemm_nt_acc`, attention, LoRA, BWD-1 staging);
//! * [`x86::axpy`] — 8-lane `y += a·x` row update (`gemm` / `gemm_tn`
//!   rank-1 inner loops, the BWD-1 `∇Yᵀ·X` saxpy form);
//! * [`x86::sparse_dot24`] — the 2:4 gather-dot: one metadata byte is
//!   decoded through the [`IDX24`] lane-permute LUT and its four kept
//!   values FMA against a 16-float window of `x` in two
//!   `vpermps`-gathered half-registers — eight multiply-adds per
//!   iteration where the scalar path does one.  This is the CPU analogue
//!   of the metadata decode sparse tensor cores do in hardware, and the
//!   same trick powers the row-compressed double-pruned transpose SpMM
//!   (Eq.-6 BWD-2) because that operand is just another `CompressedNm`.
//!
//! # Prepacked micro-tiles
//!
//! The fused [`crate::sparsity::PrepackedNm`] layout stores each row's
//! values interleaved with its *pre-decoded* `vpermps` lane indices (the
//! `IDX24` entry, computed once at prepack time), so the prepacked
//! kernels read one forward-moving stream and never touch the LUT:
//!
//! * [`x86::sparse_dot24_pre`] — per-dot over the fused stream.  One
//!   `vpmovzxbd` widens the eight stored lane bytes into the full
//!   permute index; permuting **both** windows by it and blending
//!   (`0b1111_0000`) produces the exact register `sparse_dot24` builds
//!   with its two LUT loads + `insertf128`, so results are bitwise
//!   identical to the compressed-plane kernel.
//! * [`x86::spmm_pre24_x4`] — the register-blocked SpMM micro-tile: four
//!   weight rows against one `x` row, sharing each 16-float window load
//!   (and the decode traffic it represents) across all four outputs —
//!   4×-amortized operand loads, eight live accumulator chains.  Each
//!   output's reduction replays `sparse_dot24_pre` exactly, so tiling
//!   changes nothing bitwise.
//! * [`x86::dot2`] — the dense `gemm_nt` micro-tile: one `a` row against
//!   two `b` rows, sharing every `a` load across both outputs; each
//!   output's chains/cleanup/tail replay [`x86::dot`] exactly.

use std::sync::OnceLock;

/// Instruction-set level the kernel engine dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable safe-Rust kernels — the pinned reference on every arch.
    Scalar,
    /// AVX2 + FMA microkernels (x86_64 only).
    Avx2,
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
        })
    }
}

/// Whether this process can execute the AVX2+FMA microkernels.
#[inline]
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn detect() -> SimdLevel {
    let want = std::env::var("SLOPE_SIMD").unwrap_or_default();
    match want.as_str() {
        "scalar" => SimdLevel::Scalar,
        "avx2" => {
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                eprintln!("[simd] SLOPE_SIMD=avx2 requested but AVX2+FMA unavailable; \
                           falling back to scalar");
                SimdLevel::Scalar
            }
        }
        "" | "auto" => {
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
        other => {
            eprintln!("[simd] unknown SLOPE_SIMD={other:?} (want auto|avx2|scalar); using auto");
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
    }
}

/// The process-wide dispatch level, detected once (first call) and cached.
pub fn simd_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(detect)
}

/// Clamp a requested level to what the hardware can actually run.  Every
/// `*_at` kernel entry point calls this, so passing `Avx2` on a machine
/// without it is safe (it silently runs scalar) rather than UB.
#[inline]
pub fn effective(level: SimdLevel) -> SimdLevel {
    match level {
        SimdLevel::Avx2 if !avx2_available() => SimdLevel::Scalar,
        l => l,
    }
}

/// AVX2+FMA microkernels.  Callers must hold `effective(Avx2) == Avx2`
/// (i.e. go through the dispatchers) before entering any of these.
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use std::arch::x86_64::*;

    /// Lane-permute LUT for one 2:4 metadata byte: entries 0/1 are the
    /// low-nibble group's intra-group offsets (window floats 0..4), and
    /// entries 2/3 the high-nibble group's offsets biased by 4 (window
    /// floats 4..8).  Loaded as a `__m256i` permute index whose upper
    /// four lanes are unused.
    const IDX24: [[u32; 8]; 256] = build_idx24();

    const fn build_idx24() -> [[u32; 8]; 256] {
        let mut t = [[0u32; 8]; 256];
        let mut b = 0usize;
        while b < 256 {
            t[b] = [
                (b & 3) as u32,
                ((b >> 2) & 3) as u32,
                4 + ((b >> 4) & 3) as u32,
                4 + ((b >> 6) & 3) as u32,
                0,
                0,
                0,
                0,
            ];
            b += 1;
        }
        t
    }

    /// Horizontal sum of a `__m256` in a fixed lane order (0..7), so the
    /// reduction is deterministic run-to-run.
    ///
    /// # Safety
    /// Requires AVX2 at runtime.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        // Pairwise within 128-bit halves, then across: a fixed tree that
        // does not depend on data, so results are deterministic.
        ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
    }

    /// FMA inner product over `k` elements: 4 independent 8-lane
    /// accumulator chains, an 8-wide cleanup loop, then a fixed-order
    /// horizontal reduction and a scalar `mul_add` tail.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; `a` and `b` must each hold at least
    /// `k` elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
        debug_assert!(a.len() >= k && b.len() >= k);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 32 <= k {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 16)),
                _mm256_loadu_ps(pb.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 24)),
                _mm256_loadu_ps(pb.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= k {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
        let mut s = hsum(acc);
        while i < k {
            s = (*pa.add(i)).mul_add(*pb.add(i), s);
            i += 1;
        }
        s
    }

    /// `y[..n] += a · x[..n]` — the rank-1-update row kernel for
    /// `gemm` / `gemm_tn`.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; `x` and `y` must each hold at least
    /// `n` elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32], n: usize) {
        debug_assert!(x.len() >= n && y.len() >= n);
        let av = _mm256_set1_ps(a);
        let (px, py) = (x.as_ptr(), y.as_mut_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let yv = _mm256_loadu_ps(py.add(i));
            _mm256_storeu_ps(py.add(i), _mm256_fmadd_ps(av, _mm256_loadu_ps(px.add(i)), yv));
            i += 8;
        }
        while i < n {
            *py.add(i) = a.mul_add(*px.add(i), *py.add(i));
            i += 1;
        }
    }

    /// 2:4 gather-dot over one compressed weight row: per metadata byte
    /// **pair** (four groups, eight kept values, a 16-float window of
    /// `x`), decode both bytes through [`IDX24`], `vpermps`-gather each
    /// byte's four operands from its 8-float half-window, combine the two
    /// half-registers, and FMA against the eight contiguous `vals` — then
    /// at most one whole trailing byte and one half-byte scalar tail.
    /// Two accumulator chains keep the gather streams independent.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime.  `vals.len()` (= kc) kept values and
    /// `ceil(kc/4)` metadata bytes must be present, and `xrow` must cover
    /// the dense columns (`≥ kc/4·8` floats for the full bytes it
    /// touches) — guaranteed by `CompressedNm`'s layout invariants.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sparse_dot24(xrow: &[f32], vals: &[f32], meta: &[u8]) -> f32 {
        let kc = vals.len();
        let pairs = kc / 4; // full metadata bytes (2 groups / 8 dense cols each)
        let px = xrow.as_ptr();
        let pv = vals.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut byte = 0;
        // Byte pairs: 16 dense columns / 8 kept values per iteration.
        while byte + 2 <= pairs {
            let b0 = *meta.get_unchecked(byte) as usize;
            let b1 = *meta.get_unchecked(byte + 1) as usize;
            let base = byte * 8;
            // Window for byte 0 (cols base..base+8) and byte 1 (+8..+16).
            let w0 = _mm256_loadu_ps(px.add(base));
            let w1 = _mm256_loadu_ps(px.add(base + 8));
            let g0 = _mm256_permutevar8x32_ps(
                w0,
                _mm256_loadu_si256(IDX24[b0].as_ptr() as *const __m256i),
            );
            let g1 = _mm256_permutevar8x32_ps(
                w1,
                _mm256_loadu_si256(IDX24[b1].as_ptr() as *const __m256i),
            );
            // Gathered operands live in each register's low 128 bits;
            // pack byte 1's four into the high half of byte 0's register.
            let gathered = _mm256_insertf128_ps::<1>(g0, _mm256_castps256_ps128(g1));
            let v = _mm256_loadu_ps(pv.add(byte * 4));
            if byte % 4 == 0 {
                acc0 = _mm256_fmadd_ps(gathered, v, acc0);
            } else {
                acc1 = _mm256_fmadd_ps(gathered, v, acc1);
            }
            byte += 2;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        let mut k = byte * 4;
        let mut base = byte * 8;
        // At most one full trailing byte (odd `pairs`), done scalar.
        if byte < pairs {
            let d = IDX24[*meta.get_unchecked(byte) as usize];
            s = (*px.add(base + d[0] as usize)).mul_add(*pv.add(k), s);
            s = (*px.add(base + d[1] as usize)).mul_add(*pv.add(k + 1), s);
            s = (*px.add(base + d[2] as usize)).mul_add(*pv.add(k + 2), s);
            s = (*px.add(base + d[3] as usize)).mul_add(*pv.add(k + 3), s);
            k += 4;
            base += 8;
        }
        // Odd group count: the final byte's low nibble holds one group.
        if k < kc {
            let d = IDX24[*meta.get_unchecked(pairs) as usize];
            s = (*px.add(base + d[0] as usize)).mul_add(*pv.add(k), s);
            s = (*px.add(base + d[1] as usize)).mul_add(*pv.add(k + 1), s);
        }
        s
    }

    /// 2:4 gather-dot over one **prepacked** weight row (`PrepackedNm`
    /// fused stream): per 10-slot byte-pair unit, widen the eight stored
    /// lane bytes (`vpmovzxbd`) into the permute index, gather from both
    /// 8-float half-windows, blend, and FMA against the unit's eight
    /// contiguous values — no LUT access, one stream.  The blended
    /// register is bitwise the one [`sparse_dot24`] builds (low half =
    /// byte 0's gather, high half = byte 1's; the stored lanes carry byte
    /// 1's indices in positions 4..8), the accumulator parity matches
    /// (`byte % 4`), and the trailing-byte / half-byte tails replay the
    /// same `mul_add` sequence — so prepacked output is **bit-identical**
    /// to the compressed-plane kernel.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime.  `row` must be a `PrepackedNm` 2:4
    /// fused row for `kc` kept values (`row.len() == row_stride_for`),
    /// and `xrow` must cover the dense columns, as for [`sparse_dot24`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sparse_dot24_pre(xrow: &[f32], row: &[u32], kc: usize) -> f32 {
        let pairs = kc / 4;
        let px = xrow.as_ptr();
        let ps = row.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut slot = 0;
        let mut byte = 0;
        while byte + 2 <= pairs {
            let w0 = _mm256_loadu_ps(px.add(byte * 8));
            let w1 = _mm256_loadu_ps(px.add(byte * 8 + 8));
            let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(ps.add(slot + 8) as *const __m128i));
            let g0 = _mm256_permutevar8x32_ps(w0, idx);
            let g1 = _mm256_permutevar8x32_ps(w1, idx);
            let gathered = _mm256_blend_ps::<0b1111_0000>(g0, g1);
            let v = _mm256_loadu_ps(ps.add(slot) as *const f32);
            if byte % 4 == 0 {
                acc0 = _mm256_fmadd_ps(gathered, v, acc0);
            } else {
                acc1 = _mm256_fmadd_ps(gathered, v, acc1);
            }
            slot += 10;
            byte += 2;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        let mut k = byte * 4;
        let mut base = byte * 8;
        // At most one full trailing byte (odd `pairs`): a 5-slot unit.
        if byte < pairs {
            let l = (*ps.add(slot + 4)).to_le_bytes();
            s = (*px.add(base + l[0] as usize)).mul_add(f32::from_bits(*ps.add(slot)), s);
            s = (*px.add(base + l[1] as usize)).mul_add(f32::from_bits(*ps.add(slot + 1)), s);
            s = (*px.add(base + l[2] as usize)).mul_add(f32::from_bits(*ps.add(slot + 2)), s);
            s = (*px.add(base + l[3] as usize)).mul_add(f32::from_bits(*ps.add(slot + 3)), s);
            slot += 5;
            k += 4;
            base += 8;
        }
        // Half-byte tail (odd group count): a 3-slot unit, two offsets.
        if k < kc {
            let l = (*ps.add(slot + 2)).to_le_bytes();
            s = (*px.add(base + l[0] as usize)).mul_add(f32::from_bits(*ps.add(slot)), s);
            s = (*px.add(base + l[1] as usize)).mul_add(f32::from_bits(*ps.add(slot + 1)), s);
        }
        s
    }

    /// Register-blocked 2:4 SpMM micro-tile over prepacked rows: four
    /// weight rows × one `x` row.  Each 16-float window of `x` is loaded
    /// **once** and consumed by all four outputs (4×-amortized operand
    /// traffic vs. four per-dot calls), with eight live accumulator
    /// chains (even/odd unit per output).  Per output the reduction is
    /// exactly [`sparse_dot24_pre`] — same chains, same parity, same
    /// tails — so the tile is bitwise a transparent batching and every
    /// partition/traversal bitwise pin carries over.
    ///
    /// Writes `out[0..4]`.
    ///
    /// # Safety
    /// Same requirements as [`sparse_dot24_pre`] for each of the four
    /// rows (all share `kc`); `out` must hold at least 4 elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn spmm_pre24_x4(xrow: &[f32], rows: [&[u32]; 4], kc: usize, out: &mut [f32]) {
        let pairs = kc / 4;
        let px = xrow.as_ptr();
        let prs = [rows[0].as_ptr(), rows[1].as_ptr(), rows[2].as_ptr(), rows[3].as_ptr()];
        let mut acc0 = [_mm256_setzero_ps(); 4];
        let mut acc1 = [_mm256_setzero_ps(); 4];
        let mut slot = 0;
        let mut byte = 0;
        while byte + 2 <= pairs {
            let w0 = _mm256_loadu_ps(px.add(byte * 8));
            let w1 = _mm256_loadu_ps(px.add(byte * 8 + 8));
            for e in 0..4 {
                let ps = prs[e];
                let idx =
                    _mm256_cvtepu8_epi32(_mm_loadl_epi64(ps.add(slot + 8) as *const __m128i));
                let g0 = _mm256_permutevar8x32_ps(w0, idx);
                let g1 = _mm256_permutevar8x32_ps(w1, idx);
                let gathered = _mm256_blend_ps::<0b1111_0000>(g0, g1);
                let v = _mm256_loadu_ps(ps.add(slot) as *const f32);
                if byte % 4 == 0 {
                    acc0[e] = _mm256_fmadd_ps(gathered, v, acc0[e]);
                } else {
                    acc1[e] = _mm256_fmadd_ps(gathered, v, acc1[e]);
                }
            }
            slot += 10;
            byte += 2;
        }
        for e in 0..4 {
            let ps = prs[e];
            let mut s = hsum(_mm256_add_ps(acc0[e], acc1[e]));
            let mut sl = slot;
            let mut k = byte * 4;
            let mut base = byte * 8;
            if byte < pairs {
                let l = (*ps.add(sl + 4)).to_le_bytes();
                s = (*px.add(base + l[0] as usize)).mul_add(f32::from_bits(*ps.add(sl)), s);
                s = (*px.add(base + l[1] as usize)).mul_add(f32::from_bits(*ps.add(sl + 1)), s);
                s = (*px.add(base + l[2] as usize)).mul_add(f32::from_bits(*ps.add(sl + 2)), s);
                s = (*px.add(base + l[3] as usize)).mul_add(f32::from_bits(*ps.add(sl + 3)), s);
                sl += 5;
                k += 4;
                base += 8;
            }
            if k < kc {
                let l = (*ps.add(sl + 2)).to_le_bytes();
                s = (*px.add(base + l[0] as usize)).mul_add(f32::from_bits(*ps.add(sl)), s);
                s = (*px.add(base + l[1] as usize)).mul_add(f32::from_bits(*ps.add(sl + 1)), s);
            }
            out[e] = s;
        }
    }

    /// Register-blocked dense micro-tile: one `a` row against two `b`
    /// rows, sharing every `a` load across both outputs (halved operand
    /// traffic in `gemm_nt`'s j-loop).  Each output runs [`dot`]'s exact
    /// reduction — 4 chains, 8-wide cleanup, fixed-tree `hsum`, scalar
    /// `mul_add` tail — so `dot2(a, b0, b1, k) == (dot(a, b0, k),
    /// dot(a, b1, k))` bitwise, and pairing the loop is invisible to
    /// every determinism pin.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; `a`, `b0`, `b1` must each hold at
    /// least `k` elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot2(a: &[f32], b0: &[f32], b1: &[f32], k: usize) -> (f32, f32) {
        debug_assert!(a.len() >= k && b0.len() >= k && b1.len() >= k);
        let (pa, p0, p1) = (a.as_ptr(), b0.as_ptr(), b1.as_ptr());
        let mut a00 = _mm256_setzero_ps();
        let mut a01 = _mm256_setzero_ps();
        let mut a02 = _mm256_setzero_ps();
        let mut a03 = _mm256_setzero_ps();
        let mut a10 = _mm256_setzero_ps();
        let mut a11 = _mm256_setzero_ps();
        let mut a12 = _mm256_setzero_ps();
        let mut a13 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 32 <= k {
            let av0 = _mm256_loadu_ps(pa.add(i));
            let av1 = _mm256_loadu_ps(pa.add(i + 8));
            let av2 = _mm256_loadu_ps(pa.add(i + 16));
            let av3 = _mm256_loadu_ps(pa.add(i + 24));
            a00 = _mm256_fmadd_ps(av0, _mm256_loadu_ps(p0.add(i)), a00);
            a01 = _mm256_fmadd_ps(av1, _mm256_loadu_ps(p0.add(i + 8)), a01);
            a02 = _mm256_fmadd_ps(av2, _mm256_loadu_ps(p0.add(i + 16)), a02);
            a03 = _mm256_fmadd_ps(av3, _mm256_loadu_ps(p0.add(i + 24)), a03);
            a10 = _mm256_fmadd_ps(av0, _mm256_loadu_ps(p1.add(i)), a10);
            a11 = _mm256_fmadd_ps(av1, _mm256_loadu_ps(p1.add(i + 8)), a11);
            a12 = _mm256_fmadd_ps(av2, _mm256_loadu_ps(p1.add(i + 16)), a12);
            a13 = _mm256_fmadd_ps(av3, _mm256_loadu_ps(p1.add(i + 24)), a13);
            i += 32;
        }
        while i + 8 <= k {
            let av = _mm256_loadu_ps(pa.add(i));
            a00 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p0.add(i)), a00);
            a10 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p1.add(i)), a10);
            i += 8;
        }
        let r0 = _mm256_add_ps(_mm256_add_ps(a00, a01), _mm256_add_ps(a02, a03));
        let r1 = _mm256_add_ps(_mm256_add_ps(a10, a11), _mm256_add_ps(a12, a13));
        let mut s0 = hsum(r0);
        let mut s1 = hsum(r1);
        while i < k {
            let av = *pa.add(i);
            s0 = av.mul_add(*p0.add(i), s0);
            s1 = av.mul_add(*p1.add(i), s1);
            i += 1;
        }
        (s0, s1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_never_exceeds_hardware() {
        assert_eq!(effective(SimdLevel::Scalar), SimdLevel::Scalar);
        let e = effective(SimdLevel::Avx2);
        if avx2_available() {
            assert_eq!(e, SimdLevel::Avx2);
        } else {
            assert_eq!(e, SimdLevel::Scalar);
        }
    }

    #[test]
    fn level_display_names() {
        assert_eq!(SimdLevel::Scalar.to_string(), "scalar");
        assert_eq!(SimdLevel::Avx2.to_string(), "avx2");
    }
}
