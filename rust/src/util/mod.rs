//! In-tree substrates for what an online build would pull from crates.io —
//! this environment is fully offline (DESIGN.md §2):
//!
//! * [`rng`]      — xoshiro256++ PRNG (`rand` stand-in)
//! * [`json`]     — JSON parser/writer (`serde_json` stand-in)
//! * [`bench`]    — median-of-N micro-bench harness (`criterion` stand-in)
//! * [`proptest`] — seeded property-test helper (`proptest` stand-in)
//! * [`crc32`]    — CRC-32/IEEE (`crc32fast` stand-in)
//! * [`faultfs`]  — crash-safe atomic writes + fault injection

pub mod bench;
pub mod crc32;
pub mod faultfs;
pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
