//! In-tree substrates for what an online build would pull from crates.io —
//! this environment is fully offline (DESIGN.md §2):
//!
//! * [`rng`]      — xoshiro256++ PRNG (`rand` stand-in)
//! * [`json`]     — JSON parser/writer (`serde_json` stand-in)
//! * [`bench`]    — median-of-N micro-bench harness (`criterion` stand-in)
//! * [`proptest`] — seeded property-test helper (`proptest` stand-in)

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
