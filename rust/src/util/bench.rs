//! Micro-benchmark harness — the offline stand-in for `criterion`
//! (DESIGN.md §2 substitutions).
//!
//! Median-of-N methodology matching the paper's §3.1 protocol ("we
//! conducted 1,000 iterations for each speedup experiment and reported the
//! median"): warmup, then N timed iterations, report median / p10 / p90.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
}

/// Time `f` with `iters` samples after `warmup` runs; returns the median.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchResult {
        name: name.to_string(),
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        iters,
    }
}

/// Auto-scale iteration count so one benchmark takes ≈ `budget_ms`.
/// `SLOPE_BENCH_BUDGET_MS` overrides the budget globally (CI smoke runs).
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: f64, mut f: F) -> BenchResult {
    let budget_ms = std::env::var("SLOPE_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(budget_ms);
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / one.max(1e-6)) as usize).clamp(5, 1000);
    bench(name, (iters / 10).max(1), iters, f)
}

/// Machine-readable perf-trajectory emitter.  When `SLOPE_BENCH_JSON` is
/// set, each result is appended to that path as one JSON object per line
/// (`-` = stdout): `{bench, case, threads, median_ns, p10_ns, p90_ns,
/// iters}`.  Unset ⇒ no-op, so the human tables stay the default.
pub fn emit_json(bench_name: &str, case: &str, threads: usize, r: &BenchResult) {
    let Ok(path) = std::env::var("SLOPE_BENCH_JSON") else {
        return;
    };
    let line = crate::util::json::obj(vec![
        ("bench", crate::util::json::s(bench_name)),
        ("case", crate::util::json::s(case)),
        ("threads", crate::util::json::num(threads as f64)),
        ("median_ns", crate::util::json::num(r.median_ns)),
        ("p10_ns", crate::util::json::num(r.p10_ns)),
        ("p90_ns", crate::util::json::num(r.p90_ns)),
        ("iters", crate::util::json::num(r.iters as f64)),
    ])
    .to_string();
    if path == "-" {
        println!("{line}");
    } else {
        use std::io::Write;
        match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            Ok(mut fh) => {
                let _ = writeln!(fh, "{line}");
            }
            Err(e) => eprintln!("[bench] cannot append to {path}: {e}"),
        }
    }
}

pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<44} {:>12} {:>12} {:>12} {:>7}", "benchmark", "median", "p10", "p90", "iters");
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>10.3}us {:>10.3}us {:>10.3}us {:>7}",
        r.name, r.median_us(), r.p10_ns / 1e3, r.p90_ns / 1e3, r.iters
    );
}

/// Black-box: prevent the optimizer from eliding benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_json_lines_parse_back() {
        let r = bench("emit", 1, 5, || {
            black_box(1 + 1);
        });
        let path = std::env::temp_dir().join("slope_bench_emit_test.jsonl");
        std::fs::remove_file(&path).ok();
        std::env::set_var("SLOPE_BENCH_JSON", &path);
        emit_json("bench_unit", "case-a", 4, &r);
        emit_json("bench_unit", "case-b", 1, &r);
        std::env::remove_var("SLOPE_BENCH_JSON");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = crate::util::Json::parse(line).unwrap();
            assert_eq!(j.req_str("bench").unwrap(), "bench_unit");
            assert!(j.req_f64("median_ns").unwrap() >= 0.0);
            assert!(j.req_usize("threads").unwrap() >= 1);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }
}
