//! Micro-benchmark harness — the offline stand-in for `criterion`
//! (DESIGN.md §2 substitutions).
//!
//! Median-of-N methodology matching the paper's §3.1 protocol ("we
//! conducted 1,000 iterations for each speedup experiment and reported the
//! median"): warmup, then N timed iterations, report median / p10 / p90.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
}

/// Time `f` with `iters` samples after `warmup` runs; returns the median.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchResult {
        name: name.to_string(),
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        iters,
    }
}

/// Auto-scale iteration count so one benchmark takes ≈ `budget_ms`.
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: f64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / one.max(1e-6)) as usize).clamp(5, 1000);
    bench(name, (iters / 10).max(1), iters, f)
}

pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<44} {:>12} {:>12} {:>12} {:>7}", "benchmark", "median", "p10", "p90", "iters");
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>10.3}us {:>10.3}us {:>10.3}us {:>7}",
        r.name, r.median_us(), r.p10_ns / 1e3, r.p90_ns / 1e3, r.iters
    );
}

/// Black-box: prevent the optimizer from eliding benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }
}
