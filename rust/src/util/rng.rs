//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64) — the offline
//! stand-in for the `rand` crate (DESIGN.md §2 substitutions).
//!
//! Quality notes: xoshiro256++ passes BigCrush; Box–Muller provides the
//! Gaussian variates used for weight init and synthetic data.  All
//! consumers take `&mut Rng` so runs are reproducible from a single seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let u1 = self.f64().max(f64::EPSILON);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, scale: f32) -> f32 {
        self.normal() as f32 * scale
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Snapshot the full generator state (xoshiro words + the cached
    /// Box–Muller spare) for checkpointing: [`Rng::from_state`] of this
    /// snapshot continues the exact same stream.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Self {
        Self { s, spare }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::seed_from_u64(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }
}
