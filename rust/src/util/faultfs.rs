//! Fault-injecting filesystem layer for the checkpoint writer.
//!
//! All checkpoint files go through [`write_atomic`]: serialize to a
//! sibling temp file, `sync_all`, atomically rename over the target, then
//! fsync the parent directory so the rename itself is durable.  A
//! [`FaultPlan`] — from the `SLOPE_FAULT` env var or a thread-local
//! builder ([`with_plan`], for tests) — injects crashes at the exact
//! points a real power loss or bit rot would hit:
//!
//! * `truncate_at:N`  — the temp write tears after `N` bytes and errors
//!   (torn write; the target file is never replaced);
//! * `bitflip_at:N`   — one bit of byte `N` flips silently and the write
//!   "succeeds" (latent corruption, caught by the v3 checksums);
//! * `fail_rename`    — the rename step fails (crash between temp write
//!   and publish);
//! * `kill_after_ckpt_bytes:N` — hard `process::exit(3)` once `N`
//!   cumulative checkpoint bytes have been written across the whole
//!   process (the CI kill-and-resume smoke's kill point).
//!
//! Several faults may be combined comma-separated in `SLOPE_FAULT`.

use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// What to break during [`write_atomic`].  Default: nothing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Tear the temp-file write after this many bytes, then error.
    pub truncate_at: Option<usize>,
    /// Flip one bit (bit `N % 8`) of byte `N` and report success.
    pub bitflip_at: Option<usize>,
    /// Fail the rename step (temp file written, target untouched).
    pub fail_rename: bool,
    /// `process::exit(3)` once this many cumulative bytes were written
    /// by checkpoint writes process-wide.
    pub kill_after_bytes: Option<u64>,
}

impl FaultPlan {
    pub fn is_noop(&self) -> bool {
        *self == FaultPlan::default()
    }

    /// Parse the `SLOPE_FAULT` syntax: comma-separated
    /// `truncate_at:N`, `bitflip_at:N`, `fail_rename`,
    /// `kill_after_ckpt_bytes:N`.  Unknown directives error so typos in
    /// CI scripts fail loudly instead of silently disabling the fault.
    pub fn parse(spec: &str) -> crate::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = match part.split_once(':') {
                Some((k, v)) => (k.trim(), Some(v.trim())),
                None => (part, None),
            };
            let num = |v: Option<&str>| -> crate::Result<u64> {
                v.ok_or_else(|| crate::eyre!("SLOPE_FAULT: {key} needs a :N argument"))?
                    .parse::<u64>()
                    .map_err(|e| crate::eyre!("SLOPE_FAULT: bad number in {part:?}: {e}"))
            };
            match key {
                "truncate_at" => plan.truncate_at = Some(num(val)? as usize),
                "bitflip_at" => plan.bitflip_at = Some(num(val)? as usize),
                "fail_rename" => plan.fail_rename = true,
                "kill_after_ckpt_bytes" => plan.kill_after_bytes = Some(num(val)?),
                other => return Err(crate::eyre!("SLOPE_FAULT: unknown directive {other:?}")),
            }
        }
        Ok(plan)
    }

    /// The process-wide plan from `SLOPE_FAULT` (empty plan when unset;
    /// a malformed value aborts rather than training un-faulted).
    pub fn from_env() -> FaultPlan {
        match std::env::var("SLOPE_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
                Ok(plan) => plan,
                Err(e) => {
                    eprintln!("[faultfs] {e}");
                    std::process::exit(2);
                }
            },
            _ => FaultPlan::default(),
        }
    }
}

thread_local! {
    /// Test override: takes precedence over the env plan on this thread.
    static LOCAL_PLAN: RefCell<Option<FaultPlan>> = const { RefCell::new(None) };
}

/// Cumulative bytes written by checkpoint writes, process-wide — the
/// odometer `kill_after_ckpt_bytes` reads.
static WRITTEN: AtomicU64 = AtomicU64::new(0);

/// Run `f` with `plan` active for this thread's [`write_atomic`] calls
/// (restored afterwards, even on panic-free early return).
pub fn with_plan<T>(plan: FaultPlan, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_PLAN.with(|p| p.replace(Some(plan)));
    struct Restore(Option<FaultPlan>);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_PLAN.with(|p| *p.borrow_mut() = self.0);
        }
    }
    let _restore = Restore(prev);
    f()
}

fn active_plan() -> FaultPlan {
    LOCAL_PLAN
        .with(|p| *p.borrow())
        .unwrap_or_else(FaultPlan::from_env)
}

/// Write `bytes` to `path` crash-safely: temp file in the same directory
/// → `sync_all` → atomic rename → parent-directory fsync.  On any error
/// the previous contents of `path` (if any) are still intact.  Honors
/// the active [`FaultPlan`].
pub fn write_atomic(path: &Path, bytes: &[u8]) -> crate::Result<()> {
    let plan = active_plan();
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| crate::eyre!("write_atomic: bad path {}", path.display()))?;
    let tmp = match dir {
        Some(d) => d.join(format!(".{file_name}.tmp")),
        None => std::path::PathBuf::from(format!(".{file_name}.tmp")),
    };

    let mut staged: Vec<u8>;
    let payload: &[u8] = if let Some(at) = plan.bitflip_at {
        staged = bytes.to_vec();
        if at < staged.len() {
            staged[at] ^= 1 << (at % 8);
        }
        &staged
    } else {
        bytes
    };

    use std::io::Write;
    let mut f = std::fs::File::create(&tmp)
        .map_err(|e| crate::eyre!("creating {}: {e}", tmp.display()))?;

    if let Some(at) = plan.truncate_at {
        // Torn write: flush a prefix, sync it, then fail — the temp file
        // is left behind exactly as a crash mid-write would.
        let kept = at.min(payload.len());
        f.write_all(&payload[..kept])?;
        f.sync_all()?;
        count_written(kept as u64, plan);
        return Err(crate::eyre!(
            "faultfs: injected torn write after {kept} bytes ({})",
            tmp.display()
        ));
    }

    f.write_all(payload)
        .map_err(|e| crate::eyre!("writing {}: {e}", tmp.display()))?;
    f.sync_all()
        .map_err(|e| crate::eyre!("syncing {}: {e}", tmp.display()))?;
    drop(f);
    count_written(payload.len() as u64, plan);

    if plan.fail_rename {
        return Err(crate::eyre!(
            "faultfs: injected rename failure for {}",
            path.display()
        ));
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| crate::eyre!("renaming over {}: {e}", path.display()))?;

    // Make the rename itself durable: fsync the containing directory.
    if let Some(d) = dir {
        if let Ok(dh) = std::fs::File::open(d) {
            // Directory fsync is advisory on some filesystems; a failure
            // here does not un-publish the rename.
            let _ = dh.sync_all();
        }
    }
    Ok(())
}

/// Advance the process-wide checkpoint-byte odometer, exiting if the
/// active plan's kill point was crossed.
fn count_written(n: u64, plan: FaultPlan) {
    let total = WRITTEN.fetch_add(n, Ordering::SeqCst) + n;
    if let Some(kill_at) = plan.kill_after_bytes {
        if total >= kill_at {
            eprintln!(
                "[faultfs] kill point: {total} checkpoint bytes written (limit {kill_at}); exiting"
            );
            std::process::exit(3);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("slope_faultfs_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn parse_full_spec() {
        let plan =
            FaultPlan::parse("truncate_at:12, bitflip_at:7,fail_rename,kill_after_ckpt_bytes:900")
                .unwrap();
        assert_eq!(plan.truncate_at, Some(12));
        assert_eq!(plan.bitflip_at, Some(7));
        assert!(plan.fail_rename);
        assert_eq!(plan.kill_after_bytes, Some(900));
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("explode").is_err());
        assert!(FaultPlan::parse("truncate_at").is_err());
        assert!(FaultPlan::parse("truncate_at:xyz").is_err());
    }

    #[test]
    fn clean_write_is_atomic_and_durable() {
        let path = tmp_path("clean.bin");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        assert!(!path.parent().unwrap().join(".clean.bin.tmp").exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_write_preserves_previous_contents() {
        let path = tmp_path("torn.bin");
        write_atomic(&path, b"intact contents").unwrap();
        let plan = FaultPlan { truncate_at: Some(4), ..Default::default() };
        let err = with_plan(plan, || write_atomic(&path, b"replacement")).unwrap_err();
        assert!(err.to_string().contains("torn write"), "{err}");
        assert_eq!(std::fs::read(&path).unwrap(), b"intact contents");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failed_rename_preserves_previous_contents() {
        let path = tmp_path("rename.bin");
        write_atomic(&path, b"old").unwrap();
        let plan = FaultPlan { fail_rename: true, ..Default::default() };
        assert!(with_plan(plan, || write_atomic(&path, b"new")).is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"old");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bitflip_corrupts_exactly_one_bit() {
        let path = tmp_path("flip.bin");
        let data = vec![0u8; 32];
        let plan = FaultPlan { bitflip_at: Some(9), ..Default::default() };
        with_plan(plan, || write_atomic(&path, &data)).unwrap();
        let back = std::fs::read(&path).unwrap();
        assert_eq!(back.len(), 32);
        let diff: Vec<usize> =
            back.iter().enumerate().filter(|(_, b)| **b != 0).map(|(i, _)| i).collect();
        assert_eq!(diff, vec![9]);
        assert_eq!(back[9].count_ones(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn plan_restores_after_with_plan() {
        let plan = FaultPlan { fail_rename: true, ..Default::default() };
        with_plan(plan, || assert_eq!(active_plan(), plan));
        assert!(active_plan().is_noop() || std::env::var("SLOPE_FAULT").is_ok());
    }
}
