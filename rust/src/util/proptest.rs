//! Tiny property-testing helper — the offline stand-in for `proptest`
//! (DESIGN.md §2 substitutions).
//!
//! `cases(n, seed, |g| ...)` runs a property over `n` generated cases; on
//! failure it reports the case seed so the exact inputs are replayable.

use super::rng::Rng;

/// Case generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// A dimension that is a multiple of `m` (N:M group divisibility).
    pub fn dim_multiple_of(&mut self, m: usize, max_groups: usize) -> usize {
        m * self.rng.range(1, max_groups + 1)
    }

    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal_f32(scale)).collect()
    }

    pub fn pick<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        &options[self.rng.below(options.len())]
    }
}

/// Run `prop` over `n` seeded cases; panics with the replay seed on failure.
pub fn cases<F: FnMut(&mut Gen)>(n: usize, seed: u64, mut prop: F) {
    for case in 0..n {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::seed_from_u64(case_seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (replay seed {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        cases(17, 0, |_g| count += 1);
        assert_eq!(count, 17);
    }

    #[test]
    fn generators_in_bounds() {
        cases(50, 1, |g| {
            let d = g.dim_multiple_of(4, 8);
            assert!(d % 4 == 0 && d >= 4 && d <= 32);
            let x = g.usize_in(3, 9);
            assert!((3..9).contains(&x));
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        cases(5, 2, |g| assert!(g.usize_in(0, 10) < 5, "will fail eventually"));
    }
}
