//! Minimal JSON: a recursive-descent parser + a writer — the offline
//! stand-in for `serde_json` (DESIGN.md §2 substitutions).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null); object key order is preserved (the manifest's
//! input order is semantically meaningful).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(crate::eyre!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with decent error messages.
    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        self.get(key).ok_or_else(|| crate::eyre!("missing JSON field {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| crate::eyre!("field {key:?} not a string"))
    }

    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| crate::eyre!("field {key:?} not a number"))
    }

    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| crate::eyre!("field {key:?} not a number"))
    }

    pub fn req_bool(&self, key: &str) -> crate::Result<bool> {
        self.req(key)?.as_bool().ok_or_else(|| crate::eyre!("field {key:?} not a bool"))
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for writers.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

fn write_escaped(sv: &str, out: &mut String) {
    out.push('"');
    for c in sv.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> crate::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| crate::eyre!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> crate::Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(crate::eyre!("expected {:?} at byte {}, found {:?}",
                             c as char, self.i, self.peek()? as char))
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(crate::eyre!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.eat(b'{')?;
        let mut kv = vec![];
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => return Err(crate::eyre!("expected ',' or '}}', found {:?}", c as char)),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.eat(b'[')?;
        let mut v = vec![];
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(crate::eyre!("expected ',' or ']', found {:?}", c as char)),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| crate::eyre!("{e}"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| crate::eyre!("bad \\u escape: {e}"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => return Err(crate::eyre!("bad escape \\{}", other as char)),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|e| crate::eyre!("{e}"))?;
                    out.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| crate::eyre!("{e}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| crate::eyre!("bad number {text:?}: {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Sorted-key map → Json object (for deterministic writer output).
pub fn obj_sorted(map: BTreeMap<String, Json>) -> Json {
    Json::Obj(map.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"config": {"name": "gpt-nano", "d_model": 128, "prune": true},
                       "inputs": [{"name": "tokens", "shape": [8, 129], "dtype": "int32"}],
                       "lr": 3e-4, "none": null}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("config").unwrap().req_str("name").unwrap(), "gpt-nano");
        assert_eq!(j.get("config").unwrap().req_usize("d_model").unwrap(), 128);
        assert!(j.get("config").unwrap().req_bool("prune").unwrap());
        let shape = j.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap().len(), 2);
        assert!((j.req_f64("lr").unwrap() - 3e-4).abs() < 1e-12);
        // Reparse of the writer output matches.
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#"{"s": "a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(j.req_str("s").unwrap(), "a\"b\\c\ndAé");
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn numbers() {
        let j = Json::parse("[0, -1.5, 2e3, 6e-6]").unwrap();
        let v: Vec<f64> = j.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(v, vec![0.0, -1.5, 2000.0, 6e-6]);
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }
}
