//! CRC-32 (IEEE 802.3, polynomial 0xEDB88320) — the offline stand-in for
//! the `crc32fast` crate (DESIGN.md §2 substitutions).  Used by the
//! checkpoint format v3 for per-record and whole-file integrity checks.
//!
//! The table is built at compile time, so `crc32` has no runtime setup
//! and no global state.

/// Reflected-polynomial lookup table, one entry per byte value.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 of `bytes` in one call.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

/// Streaming CRC-32 (feed chunks, then [`Hasher::finalize`]).
#[derive(Clone, Copy, Debug)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference values from the IEEE CRC-32 check sequence.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 + 3) as u8).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let data = vec![0xA5u8; 256];
        let base = crc32(&data);
        for byte in 0..data.len() {
            let mut flipped = data.clone();
            flipped[byte] ^= 1 << (byte % 8);
            assert_ne!(crc32(&flipped), base, "flip at byte {byte} undetected");
        }
    }
}
