//! Offline stand-in for the `xla` crate (xla-rs 0.1.6) — the build
//! environment has no network and no PJRT plugin, so the real bindings
//! cannot be fetched (DESIGN.md §2 substitutions).
//!
//! Two tiers of fidelity:
//! * **Host literals are real.**  [`Literal`] is a working host tensor
//!   (f32 / i32 / tuple, shape-carrying, `vec1`/`scalar`/`reshape`/
//!   `to_vec`/`to_tuple`), because the coordinator's `Store`, checkpoint
//!   format, and every artifact-free test build on it.
//! * **PJRT surfaces are gated.**  `PjRtClient::cpu()` succeeds (so
//!   sessions open and manifests load), but parsing/compiling/executing
//!   HLO returns a descriptive error.  Code paths that need real XLA are
//!   exactly the ones that need `make artifacts`, and they skip or fail
//!   loudly with this message instead of segfaulting.
//!
//! Swapping the real xla-rs back in is a one-line change in
//! `rust/Cargo.toml`; every signature here matches the 0.1.6 call sites
//! used by the coordinator.

use std::fmt;
use std::path::Path;

/// Stub error type (the `xla::Error` role): message-only, `Display`able.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "offline xla stub: PJRT compile/execute unavailable \
                        (link the real xla-rs to run AOT artifacts)";

/// Element dtypes the coordinator uses.  `non_exhaustive` mirrors the
/// real bindings' wider dtype set, so downstream `match` arms keep their
/// catch-all without tripping `unreachable_patterns`.
#[non_exhaustive]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host tensor literal: typed storage plus dims (empty dims = scalar).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Array shape accessor (`lit.array_shape()?.dims()`).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Element types storable in a [`Literal`].
pub trait ArrayElement: Copy {
    const TY: ElementType;
    #[doc(hidden)]
    fn make_literal(v: Vec<Self>) -> Literal
    where
        Self: Sized;
    #[doc(hidden)]
    fn extract(lit: &Literal) -> Result<Vec<Self>>
    where
        Self: Sized;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn make_literal(v: Vec<Self>) -> Literal {
        let dims = vec![v.len() as i64];
        Literal { data: Data::F32(v), dims }
    }
    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            other => Err(Error(format!("literal is not F32: {other:?}"))),
        }
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn make_literal(v: Vec<Self>) -> Literal {
        let dims = vec![v.len() as i64];
        Literal { data: Data::I32(v), dims }
    }
    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            other => Err(Error(format!("literal is not S32: {other:?}"))),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: ArrayElement>(data: &[T]) -> Literal {
        T::make_literal(data.to_vec())
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: ArrayElement>(v: T) -> Literal {
        let mut lit = T::make_literal(vec![v]);
        lit.dims = vec![];
        lit
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Same storage under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count {} != {n}",
                self.dims,
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.data {
            Data::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.data {
            Data::F32(_) => Ok(ElementType::F32),
            Data::I32(_) => Ok(ElementType::S32),
            Data::Tuple(_) => Err(Error("tuple literal has no element type".into())),
        }
    }

    /// Copy the elements to a host `Vec` (dtype-checked).
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Build a tuple literal (what executables return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let dims = vec![elems.len() as i64];
        Literal { data: Data::Tuple(elems), dims }
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            other => Err(Error(format!("not a tuple literal: {other:?}"))),
        }
    }
}

// ---- PJRT surfaces (gated) --------------------------------------------

/// Parsed HLO module handle — parsing needs real XLA, so construction
/// fails in the stub.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(Error(format!("{STUB_MSG}; cannot parse {}", path.as_ref().display())))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// CPU PJRT client handle.  Opening succeeds so artifact-free flows
/// (manifest inspection, store ops) work; `compile` is the gate.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "cpu-offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.to_string()))
    }
}

pub struct PjRtLoadedExecutable;

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB_MSG.to_string()))
    }
}

impl PjRtLoadedExecutable {
    /// Matches the xla-rs call shape `exe.execute::<&Literal>(&args)`.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err(), "dtype-checked reads");
    }

    #[test]
    fn scalar_and_reshape_guards() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.array_shape().unwrap().dims().len(), 0);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(Literal::vec1(&[1.0f32; 6]).reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_destructuring() {
        let t = Literal::tuple(vec![Literal::scalar(1i32), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }

    #[test]
    fn pjrt_surfaces_are_gated_not_absent() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-offline-stub");
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("offline xla stub"));
    }
}
