//! SIMD-vs-scalar parity for the runtime-dispatched kernel engine.
//!
//! The contract (`backend::simd` docs):
//!
//! * **within a level** results are bitwise identical across thread
//!   counts, partitions, and traversal orders — pinned here at *forced*
//!   `Avx2` (which `effective` clamps to scalar on hardware without it,
//!   so the suite is meaningful everywhere and strictest on AVX2 hosts);
//! * **across levels** results agree to tight relative tolerance (FMA
//!   contraction reassociates float reductions) — pinned over ragged
//!   shapes, all three schemes, misaligned/tail column counts, and
//!   threads {1, 4};
//! * on **small-integer inputs** every multiply-add is exact, so FMA
//!   cannot round differently and the levels must agree **bitwise** —
//!   an end-to-end check that the lane-permute gather reads exactly the
//!   operands the packed metadata names.
//!
//! CI additionally runs the whole suite (including the `host_train`
//! gradient checks) under `SLOPE_SIMD=scalar` to prove the fallback path
//! is byte-for-byte the pre-SIMD engine.

use slope::backend::simd::effective;
use slope::backend::{avx2_available, dot_at, dot_scalar, gemm_into_at, gemm_nt_acc_into_at,
                     gemm_nt_into_at, gemm_tn_into_at, sparse_dot_at, sparse_dot_scalar,
                     spmm_prepacked_with_at, spmm_rowmajor_with_at, spmm_tiled_with_at,
                     ParallelPolicy, PartitionStrategy, SimdLevel};
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme, PrepackedNm};
use slope::tensor::Matrix;
use slope::util::proptest::cases;
use slope::util::Rng;

const SCHEMES: [(usize, usize); 3] = [(1, 2), (2, 4), (2, 8)];

fn policy(threads: usize, partition: PartitionStrategy) -> ParallelPolicy {
    ParallelPolicy { threads, min_rows_per_task: 1, partition }
}

/// Relative-tolerance matrix compare: FMA reassociation over a length-k
/// reduction of O(1) operands perturbs at the order of a few ulps scaled
/// by the partial-sum magnitude; 1e-4 relative is orders of magnitude
/// above that while far below any indexing mistake.
fn assert_close(a: &Matrix, b: &Matrix, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        let tol = 1e-4f32 * 1.0f32.max(x.abs());
        assert!((x - y).abs() <= tol, "{ctx}: elem {i}: {x} vs {y}");
    }
}

/// Fill a matrix with small integers (|v| ≤ 4): products ≤ 16 and the
/// reductions here stay far below 2^24, so every f32 operation — FMA or
/// not — is exact and all levels must agree bitwise.
fn small_int_matrix(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = rng.below(9) as f32 - 4.0;
    }
    m
}

#[test]
fn prop_spmm_levels_agree_within_tolerance() {
    cases(60, 0x51D0, |g| {
        let &(n, m) = g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        // Ragged everything: group counts (odd counts hit the half-byte
        // metadata tail), batch, and output rows (tail of the 4-row ILP
        // quad and of the AVX2 byte-pair loop).
        let cols = s.m * g.usize_in(1, 18);
        let rows = g.usize_in(1, 41);
        let batch = g.usize_in(1, 9);
        let x = Matrix::randn(batch, cols, 1.0, &mut g.rng);
        let w = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, s);
        let want = spmm_rowmajor_with_at(SimdLevel::Scalar, &x, &c, &ParallelPolicy::serial());
        for threads in [1usize, 4] {
            for part in [PartitionStrategy::Rows, PartitionStrategy::Cols] {
                let p = policy(threads, part);
                let got = spmm_rowmajor_with_at(SimdLevel::Avx2, &x, &c, &p);
                assert_close(&got, &want, &format!("{s} t={threads} {part:?}"));
            }
        }
        let tile = g.usize_in(1, 17);
        let pt = policy(4, PartitionStrategy::Auto);
        let got = spmm_tiled_with_at(SimdLevel::Avx2, &x, &c, tile, &pt);
        assert_close(&got, &want, &format!("{s} tiled tile={tile}"));
    });
}

#[test]
fn prop_spmm_levels_agree_bitwise_on_small_integers() {
    cases(40, 0x51D1, |g| {
        let &(n, m) = g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let cols = s.m * g.usize_in(1, 18);
        let rows = g.usize_in(1, 33);
        let batch = g.usize_in(1, 6);
        let x = small_int_matrix(batch, cols, &mut g.rng);
        let w = small_int_matrix(rows, cols, &mut g.rng);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, s);
        let p = policy(1, PartitionStrategy::Auto);
        let scalar = spmm_rowmajor_with_at(SimdLevel::Scalar, &x, &c, &p);
        let simd = spmm_rowmajor_with_at(SimdLevel::Avx2, &x, &c, &p);
        // Exact arithmetic ⇒ reassociation is invisible ⇒ any difference
        // is a wrong gather index, not a rounding artifact.
        assert_eq!(simd, scalar, "{s} {batch}x{cols} -> {rows}");
    });
}

#[test]
fn avx2_level_is_thread_and_traversal_invariant() {
    // Within a level (here: forced Avx2, clamped to hardware) results
    // must stay bitwise identical across thread counts, partitions, and
    // rowmajor-vs-tiled traversal — the same contract the scalar engine
    // always had, which is what keeps the crash-recovery and decode
    // bitwise pins level-agnostic.
    let mut rng = Rng::seed_from_u64(7);
    let s = NmScheme::TWO_FOUR;
    let x = Matrix::randn(13, 96, 1.0, &mut rng); // ragged batch
    let w = Matrix::randn(37, 96, 1.0, &mut rng); // ragged outs
    let mask = random_row_mask(37, 96, s, &mut rng);
    let c = CompressedNm::compress(&w, &mask, s);
    let lvl = SimdLevel::Avx2;
    let base = spmm_rowmajor_with_at(lvl, &x, &c, &ParallelPolicy::serial());
    for threads in [2usize, 4, 7] {
        for part in [PartitionStrategy::Auto, PartitionStrategy::Rows, PartitionStrategy::Cols] {
            let p = policy(threads, part);
            assert_eq!(spmm_rowmajor_with_at(lvl, &x, &c, &p), base, "t={threads} {part:?}");
            for tile in [1usize, 5, 16] {
                assert_eq!(spmm_tiled_with_at(lvl, &x, &c, tile, &p), base,
                           "tiled t={threads} tile={tile} {part:?}");
            }
        }
    }
}

#[test]
fn sparse_dot_tail_shapes_agree_across_levels() {
    // Column counts chosen to hit every remainder path of the AVX2 2:4
    // gather-dot: no full byte (4), one trailing full byte (8), full
    // byte + half byte (12), exactly one byte pair (16), pairs + half
    // byte (20, 36), long even/odd mixes (64, 100).
    let mut rng = Rng::seed_from_u64(11);
    let s = NmScheme::TWO_FOUR;
    for cols in [4usize, 8, 12, 16, 20, 36, 64, 100] {
        let x = Matrix::randn(1, cols, 1.0, &mut rng);
        let w = Matrix::randn(9, cols, 1.0, &mut rng);
        let mask = random_row_mask(9, cols, s, &mut rng);
        let c = CompressedNm::compress(&w, &mask, s);
        let kc = c.kcols();
        let rmb = c.row_meta_bytes();
        for o in 0..c.rows {
            let vals = &c.values[o * kc..(o + 1) * kc];
            let meta = &c.meta[o * rmb..(o + 1) * rmb];
            let bits = s.offset_bits();
            let scalar = sparse_dot_scalar(x.row(0), vals, meta, s.n, s.m, bits);
            let fast = sparse_dot_at(SimdLevel::Avx2, x.row(0), vals, meta, s.n, s.m, bits);
            let tol = 1e-4f32 * 1.0f32.max(scalar.abs());
            assert!((fast - scalar).abs() <= tol, "cols={cols} row={o}: {fast} vs {scalar}");
            // And the scalar-level dispatch stays pinned bitwise.
            let pinned = sparse_dot_at(SimdLevel::Scalar, x.row(0), vals, meta, s.n, s.m, bits);
            assert_eq!(pinned.to_bits(), scalar.to_bits(), "cols={cols} row={o}");
        }
    }
}

#[test]
fn prop_gemm_family_levels_agree() {
    cases(40, 0x51D2, |g| {
        let m = g.usize_in(1, 17);
        let k = g.usize_in(1, 70); // ragged k: hits the 32/8/scalar dot tails
        let n = g.usize_in(1, 23);
        let a = Matrix::randn(m, k, 1.0, &mut g.rng);
        let b = Matrix::randn(k, n, 1.0, &mut g.rng);
        let bt = b.transpose();
        let p = policy(*g.pick(&[1usize, 4]), PartitionStrategy::Auto);

        let mut want = Matrix::zeros(m, n);
        let mut got = Matrix::zeros(m, n);
        gemm_into_at(SimdLevel::Scalar, &a, &b, &mut want, &p);
        gemm_into_at(SimdLevel::Avx2, &a, &b, &mut got, &p);
        assert_close(&got, &want, "gemm");

        gemm_nt_into_at(SimdLevel::Scalar, &a, &bt, &mut want, &p);
        gemm_nt_into_at(SimdLevel::Avx2, &a, &bt, &mut got, &p);
        assert_close(&got, &want, "gemm_nt");
        // Forced column stripes run the same per-element dot.
        let pc = policy(4, PartitionStrategy::Cols);
        let mut got_c = Matrix::zeros(m, n);
        gemm_nt_into_at(SimdLevel::Avx2, &a, &bt, &mut got_c, &pc);
        assert_eq!(got_c, got, "gemm_nt col stripes must match rows bitwise within a level");

        let at = a.transpose();
        let mut want_tn = Matrix::zeros(m, n);
        let mut got_tn = Matrix::zeros(m, n);
        gemm_tn_into_at(SimdLevel::Scalar, &at, &b, &mut want_tn, &p);
        gemm_tn_into_at(SimdLevel::Avx2, &at, &b, &mut got_tn, &p);
        assert_close(&got_tn, &want_tn, "gemm_tn");

        // Accumulating form: same base, both levels on top.
        let base = Matrix::randn(m, n, 1.0, &mut g.rng);
        let mut acc_s = base.clone();
        let mut acc_v = base.clone();
        gemm_nt_acc_into_at(SimdLevel::Scalar, &a, &bt, &mut acc_s, &p);
        gemm_nt_acc_into_at(SimdLevel::Avx2, &a, &bt, &mut acc_v, &p);
        assert_close(&acc_v, &acc_s, "gemm_nt_acc");
    });
}

#[test]
fn prop_dot_levels_agree_and_exact_on_integers() {
    cases(60, 0x51D3, |g| {
        let k = g.usize_in(0, 130);
        let a: Vec<f32> = (0..k).map(|_| g.rng.normal_f32(1.0)).collect();
        let b: Vec<f32> = (0..k).map(|_| g.rng.normal_f32(1.0)).collect();
        let want = dot_scalar(&a, &b, k);
        let got = dot_at(SimdLevel::Avx2, &a, &b, k);
        let tol = 1e-4f32 * 1.0f32.max(want.abs());
        assert!((got - want).abs() <= tol, "k={k}: {got} vs {want}");
        assert_eq!(dot_at(SimdLevel::Scalar, &a, &b, k).to_bits(), want.to_bits(), "k={k}");

        let ai: Vec<f32> = (0..k).map(|_| g.rng.below(9) as f32 - 4.0).collect();
        let bi: Vec<f32> = (0..k).map(|_| g.rng.below(9) as f32 - 4.0).collect();
        assert_eq!(dot_at(SimdLevel::Avx2, &ai, &bi, k).to_bits(),
                   dot_scalar(&ai, &bi, k).to_bits(), "integer dot k={k}");
    });
}

#[test]
fn prop_prepacked_matches_compressed_bitwise() {
    // The tentpole contract: at the SAME level, the fused prepacked plane
    // is a pure layout change — every dot replays the compressed kernel's
    // reduction order exactly, so the output is bitwise identical across
    // schemes, ragged shapes, thread counts, and partition strategies.
    cases(60, 0x51D4, |g| {
        let &(n, m) = g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let cols = s.m * g.usize_in(1, 18);
        let rows = g.usize_in(1, 41); // rows % 4 sweeps the quad-tile tail
        let batch = g.usize_in(1, 9);
        let x = Matrix::randn(batch, cols, 1.0, &mut g.rng);
        let w = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, s);
        let pre = PrepackedNm::prepack(&c);
        assert_eq!(pre.unpack(), c, "{s} prepack round-trip");
        for lvl in [SimdLevel::Scalar, SimdLevel::Avx2] {
            for threads in [1usize, 4] {
                for part in
                    [PartitionStrategy::Auto, PartitionStrategy::Rows, PartitionStrategy::Cols]
                {
                    let p = policy(threads, part);
                    let want = spmm_rowmajor_with_at(lvl, &x, &c, &p);
                    let got = spmm_prepacked_with_at(lvl, &x, &pre, &p);
                    assert_eq!(got, want, "{s} {lvl:?} t={threads} {part:?}");
                }
            }
        }
    });
}

#[test]
fn prop_prepacked_levels_agree_within_tolerance() {
    // Across levels the prepacked path inherits the compressed contract:
    // tight relative tolerance on random floats (FMA reassociation only).
    cases(40, 0x51D5, |g| {
        let &(n, m) = g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let cols = s.m * g.usize_in(1, 18);
        let rows = g.usize_in(1, 33);
        let batch = g.usize_in(1, 6);
        let x = Matrix::randn(batch, cols, 1.0, &mut g.rng);
        let w = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        let pre = PrepackedNm::prepack(&CompressedNm::compress(&w, &mask, s));
        let p = policy(1, PartitionStrategy::Auto);
        let want = spmm_prepacked_with_at(SimdLevel::Scalar, &x, &pre, &p);
        let got = spmm_prepacked_with_at(SimdLevel::Avx2, &x, &pre, &p);
        assert_close(&got, &want, &format!("prepacked {s} {batch}x{cols} -> {rows}"));
    });
}

#[test]
fn prop_prepacked_levels_agree_bitwise_on_small_integers() {
    // Exact arithmetic ⇒ any cross-level difference is a wrong stream
    // offset or lane index, not rounding — an end-to-end audit that the
    // fused layout decodes to exactly the operands the metadata names.
    cases(40, 0x51D6, |g| {
        let &(n, m) = g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let cols = s.m * g.usize_in(1, 18);
        let rows = g.usize_in(1, 33);
        let batch = g.usize_in(1, 6);
        let x = small_int_matrix(batch, cols, &mut g.rng);
        let w = small_int_matrix(rows, cols, &mut g.rng);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        let pre = PrepackedNm::prepack(&CompressedNm::compress(&w, &mask, s));
        let p = policy(1, PartitionStrategy::Auto);
        let scalar = spmm_prepacked_with_at(SimdLevel::Scalar, &x, &pre, &p);
        let simd = spmm_prepacked_with_at(SimdLevel::Avx2, &x, &pre, &p);
        assert_eq!(simd, scalar, "prepacked {s} {batch}x{cols} -> {rows}");
    });
}

#[test]
fn prepacked_remainder_paths_stay_pinned() {
    // Deterministic sweep of every micro-tile remainder: weight-row
    // counts covering each quad tail (rows % 4 ∈ {0,1,2,3}) crossed with
    // 2:4 column counts hitting the byte-pair loop, the trailing full
    // byte, and the half-byte metadata tail — each pinned bitwise against
    // the compressed path at both levels.
    let mut rng = Rng::seed_from_u64(23);
    let s = NmScheme::TWO_FOUR;
    for rows in [1usize, 2, 3, 4, 5, 7, 8, 9] {
        for cols in [4usize, 8, 12, 16, 20, 36, 64, 100] {
            let x = Matrix::randn(3, cols, 1.0, &mut rng);
            let w = Matrix::randn(rows, cols, 1.0, &mut rng);
            let mask = random_row_mask(rows, cols, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            let pre = PrepackedNm::prepack(&c);
            for lvl in [SimdLevel::Scalar, SimdLevel::Avx2] {
                let p = ParallelPolicy::serial();
                assert_eq!(spmm_prepacked_with_at(lvl, &x, &pre, &p),
                           spmm_rowmajor_with_at(lvl, &x, &c, &p),
                           "{rows}x{cols} {lvl:?}");
            }
        }
    }
}

#[test]
fn effective_clamps_to_hardware() {
    // Requesting Avx2 anywhere is sound: on hardware without it the
    // dispatchers run scalar instead of executing illegal instructions.
    assert_eq!(effective(SimdLevel::Scalar), SimdLevel::Scalar);
    if avx2_available() {
        assert_eq!(effective(SimdLevel::Avx2), SimdLevel::Avx2);
    } else {
        assert_eq!(effective(SimdLevel::Avx2), SimdLevel::Scalar);
        // And the Avx2-tagged entry points equal scalar bitwise.
        let mut rng = Rng::seed_from_u64(3);
        let x = Matrix::randn(3, 32, 1.0, &mut rng);
        let w = Matrix::randn(8, 32, 1.0, &mut rng);
        let mask = random_row_mask(8, 32, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let p = ParallelPolicy::serial();
        assert_eq!(spmm_rowmajor_with_at(SimdLevel::Avx2, &x, &c, &p),
                   spmm_rowmajor_with_at(SimdLevel::Scalar, &x, &c, &p));
    }
}
