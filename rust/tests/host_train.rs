//! Host training executor: finite-difference gradient checks, the
//! double-pruned-backward pin, and thread-count determinism.
//!
//! The FD checks are **directional**: for a parameter tensor `θ` with
//! analytic gradient `g`, the derivative of the loss along `u = g/‖g‖`
//! is `‖g‖`; comparing it against the central difference
//! `(L(θ+εu) − L(θ−εu)) / 2ε` aggregates every element of the tensor
//! into one well-conditioned number (the f32 forward's rounding noise
//! averages out instead of dominating per-element quotients), which is
//! what lets the check hold to ≤1e-3 *relative* error in f32.
//!
//! The Eq.-6 pin works by the one structural fact of the method: the
//! forward depends only on `mask_r`, while `∇X = ∇Y·W^{R,C}` consumes
//! `mask_rc`.  Two models sharing every parameter but differing in
//! `mask_rc` (true double-pruned vs `mask_rc := mask_r`) must produce
//! bitwise-identical losses and last-layer weight gradients, exact
//! FD-matching *upstream* gradients only in the `mask_rc = mask_r`
//! model, and *different* upstream gradients between the two — a plain
//! `∇Y·Wᵀ` backward could not produce that difference.

use slope::backend::ParallelPolicy;
use slope::runtime::{write_host_train_artifact, HostTrainModel, Manifest, Store};
use slope::util::Rng;
use std::path::PathBuf;

fn setup(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("slope_host_train_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    write_host_train_artifact(&dir, &format!("fd-{tag}")).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    (dir, manifest)
}

fn train_tokens(manifest: &Manifest, seed: u64) -> Vec<i32> {
    let c = &manifest.config;
    let mut rng = Rng::seed_from_u64(seed);
    (0..c.batch_size * (c.seq_len + 1))
        .map(|_| rng.below(c.vocab_size) as i32)
        .collect()
}

/// Export a freshly initialized model into a new store (params + masks;
/// opt zeros), so FD probes can rebuild identical models from it.
fn export_model(model: &mut HostTrainModel, with_lora: bool) -> Store {
    let mut store = Store::new();
    model.export_params(&mut store).unwrap();
    model.export_opt(&mut store).unwrap();
    model.export_masks(&mut store).unwrap();
    if with_lora {
        model.export_lora(&mut store).unwrap();
    }
    store
}

/// Overwrite every `masks.*_rc` plane with its `_r` counterpart (turning
/// Eq. 6 into the exact transpose on the support).
fn flatten_rc_masks(manifest: &Manifest, store: &mut Store) {
    for layer in 0..manifest.config.n_layer {
        for wname in ["wqkv", "wproj", "wup", "wdown"] {
            let rname = format!("masks.blocks.{layer}.{wname}_r");
            let r = store.read_f32(&rname).unwrap();
            let dims: Vec<usize> = store
                .get(&rname)
                .unwrap()
                .array_shape()
                .unwrap()
                .dims()
                .iter()
                .map(|d| *d as usize)
                .collect();
            store
                .put_f32(&format!("masks.blocks.{layer}.{wname}_rc"), &dims, &r)
                .unwrap();
        }
    }
}

fn loss_from(manifest: &Manifest, store: &Store, tokens: &[i32], with_lora: bool) -> f32 {
    let mut m = HostTrainModel::from_store(manifest, store, ParallelPolicy::serial()).unwrap();
    m.eval_loss(tokens, with_lora).unwrap()
}

/// Directional finite-difference check for one parameter plane.
/// Returns `(numeric, analytic)` directional derivatives.
fn directional_fd(manifest: &Manifest, store: &mut Store, suffix: &str, tokens: &[i32],
                  with_lora: bool, eps: f32) -> (f64, f64) {
    let mut model =
        HostTrainModel::from_store(manifest, store, ParallelPolicy::serial()).unwrap();
    model.loss_and_grad(tokens, with_lora).unwrap();
    let g = model
        .grad_dense(suffix)
        .unwrap_or_else(|| panic!("no gradient for {suffix}"));
    let norm = (g.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()).sqrt();
    assert!(norm > 1e-6, "{suffix}: gradient too small to probe ({norm})");
    let plane = if let Some(rest) = suffix.strip_prefix("lora.") {
        format!("lora.{rest}")
    } else {
        format!("params.{suffix}")
    };
    let base = store.read_f32(&plane).unwrap();
    assert_eq!(base.len(), g.data.len(), "{plane} shape mismatch");
    let lit = store.get(&plane).unwrap();
    let dims: Vec<usize> = lit
        .array_shape()
        .unwrap()
        .dims()
        .iter()
        .map(|d| *d as usize)
        .collect();
    let mut losses = [0.0f32; 2];
    for (i, sign) in [1.0f32, -1.0].iter().enumerate() {
        let perturbed: Vec<f32> = base
            .iter()
            .zip(&g.data)
            .map(|(w, gv)| w + sign * eps * (gv / norm as f32))
            .collect();
        store.put_f32(&plane, &dims, &perturbed).unwrap();
        losses[i] = loss_from(manifest, store, tokens, with_lora);
    }
    store.put_f32(&plane, &dims, &base).unwrap();
    let numeric = (losses[0] as f64 - losses[1] as f64) / (2.0 * eps as f64);
    (numeric, norm)
}

fn assert_fd(manifest: &Manifest, store: &mut Store, suffix: &str, tokens: &[i32],
             with_lora: bool) {
    let eps = 2e-2f32;
    let (numeric, analytic) = directional_fd(manifest, store, suffix, tokens, with_lora, eps);
    let rel = (numeric - analytic).abs() / analytic.abs().max(numeric.abs()).max(1e-12);
    assert!(
        rel <= 1e-3,
        "{suffix}: directional FD {numeric:.6e} vs analytic {analytic:.6e} (rel {rel:.2e})"
    );
}

#[test]
fn fd_gradient_check_pruned_linear_and_dense_leaves() {
    // True double-pruned model: the gradients checked here are the ones
    // whose backward path contains no Eq.-6 approximation — the last
    // block's pruned linears' own ∇W (masked packed grad_weight), its
    // bias, and the final norm — so FD must agree to ≤1e-3.
    let (dir, manifest) = setup("pruned");
    let tokens = train_tokens(&manifest, 11);
    let mut model = HostTrainModel::init(&manifest, 5, ParallelPolicy::serial()).unwrap();
    let mut store = export_model(&mut model, false);
    let last = manifest.config.n_layer - 1;
    assert_fd(&manifest, &mut store, &format!("blocks.{last}.wdown"), &tokens, false);
    assert_fd(&manifest, &mut store, &format!("blocks.{last}.bdown"), &tokens, false);
    assert_fd(&manifest, &mut store, "lnf_g", &tokens, false);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fd_gradient_check_full_backward_without_double_pruning() {
    // `mask_rc := mask_r` makes the whole backward exact (the ∇X operand
    // becomes the true masked transpose), so FD must match EVERY leaf —
    // embeddings and early-layer weights included.  NOTE: a row-exact
    // mask is not column-N:M, so these linears restore through the DENSE
    // masked route — this test validates the complete backward chain
    // (CE, tied head, layer norms, attention, GELU, masked linears, bias
    // sums, embedding scatter), while the packed `w_t` operand itself is
    // pinned bit-exactly against `mask_rc ⊙ W` (init + post-update) by
    // the unit tests inside `runtime/host_train.rs`.
    let (dir, manifest) = setup("exact");
    let tokens = train_tokens(&manifest, 13);
    let mut model = HostTrainModel::init(&manifest, 6, ParallelPolicy::serial()).unwrap();
    let mut store = export_model(&mut model, false);
    flatten_rc_masks(&manifest, &mut store);
    for suffix in ["tok_emb", "pos_emb", "blocks.0.wproj", "blocks.0.wup", "blocks.0.ln1_g",
                   "blocks.1.wqkv", "blocks.1.bqkv"] {
        assert_fd(&manifest, &mut store, suffix, &tokens, false);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fd_gradient_check_adapters() {
    let (dir, manifest) = setup("lora");
    let tokens = train_tokens(&manifest, 17);
    let mut model = HostTrainModel::init(&manifest, 7, ParallelPolicy::serial()).unwrap();
    model.lora_init(3).unwrap();
    // A few lazy steps so the up factors grow off zero: a nonzero up
    // feeds the down gradient, and larger factor magnitudes keep the
    // directional FD quotient well above f32 forward noise.
    for _ in 0..5 {
        let _ = model.train_step_lora(&tokens).unwrap();
    }
    let mut store = export_model(&mut model, true);
    let last = manifest.config.n_layer - 1;
    assert_fd(&manifest, &mut store, &format!("lora.blocks.{last}.wdown_up"), &tokens, true);
    assert_fd(&manifest, &mut store, &format!("lora.blocks.{last}.wdown_down"), &tokens,
              true);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn grad_input_provably_uses_double_pruned_transpose() {
    use slope::sparsity::{double_prune_mask, Mask, NmScheme};
    use slope::tensor::Matrix;
    // Two models sharing every parameter, every `mask_r`, and the packed
    // forward route, differing ONLY in `mask_rc`: model B keeps the true
    // double-pruned masks; model A gets alternative — equally valid
    // column-N:M, ⊆ mask_r — masks derived by magnitude of an unrelated
    // random matrix.  The forward and the last linear's ∇W never touch
    // `mask_rc`, so those must be bit-identical; the upstream gradients
    // flow through `∇X = ∇Y·W^{R,C}` and MUST differ.  A backward using
    // plain `Wᵀ` (or `mask_r ⊙ W`) could not tell the two models apart.
    let (dir, manifest) = setup("eq6pin");
    let tokens = train_tokens(&manifest, 19);
    let mut model = HostTrainModel::init(&manifest, 9, ParallelPolicy::serial()).unwrap();
    let store_b = export_model(&mut model, false); // true W^{R,C}
    let mut model_a =
        HostTrainModel::from_store(&manifest, &store_b, ParallelPolicy::serial()).unwrap();
    let mut store_a = export_model(&mut model_a, false);
    let mut rng = Rng::seed_from_u64(0xA17E);
    let mut changed = 0usize;
    for layer in 0..manifest.config.n_layer {
        let (n, m) = manifest.scheme_for_layer(layer);
        let scheme = NmScheme::new(n, m);
        for wname in ["wqkv", "wproj", "wup", "wdown"] {
            if !manifest.is_pruned(layer, wname) {
                continue;
            }
            let rname = format!("masks.blocks.{layer}.{wname}_r");
            let r = store_a.read_matrix(&rname).unwrap();
            let mask_r = Mask {
                rows: r.rows,
                cols: r.cols,
                keep: r.data.iter().map(|v| *v != 0.0).collect(),
            };
            // Alternative double-pruned mask: same rule, unrelated
            // magnitudes — still column-N:M and a subset of mask_r.
            let decoy = Matrix::randn(r.rows, r.cols, 1.0, &mut rng);
            let rc2 = double_prune_mask(&decoy, &mask_r, scheme);
            let rc_old = store_a
                .read_f32(&format!("masks.blocks.{layer}.{wname}_rc"))
                .unwrap();
            let rc2_mat = rc2.to_matrix();
            changed += rc_old
                .iter()
                .zip(&rc2_mat.data)
                .filter(|(a, b)| **a != **b)
                .count();
            store_a
                .put_f32(&format!("masks.blocks.{layer}.{wname}_rc"),
                         &[r.rows, r.cols], &rc2_mat.data)
                .unwrap();
        }
    }
    assert!(changed > 0, "alternative mask_rc equals the original — vacuous pin");

    let mut mb =
        HostTrainModel::from_store(&manifest, &store_b, ParallelPolicy::serial()).unwrap();
    let mut ma =
        HostTrainModel::from_store(&manifest, &store_a, ParallelPolicy::serial()).unwrap();
    let loss_b = mb.loss_and_grad(&tokens, false).unwrap();
    let loss_a = ma.loss_and_grad(&tokens, false).unwrap();
    // Forward consumes mask_r only ⇒ identical losses, bit for bit (both
    // models run the same packed forward operands).
    assert_eq!(loss_a.to_bits(), loss_b.to_bits(), "forward must ignore mask_rc");
    // The last pruned linear's own ∇W sees no Eq.-6 hop ⇒ identical.
    let last = manifest.config.n_layer - 1;
    let gb = mb.grad_dense(&format!("blocks.{last}.wdown")).unwrap();
    let ga = ma.grad_dense(&format!("blocks.{last}.wdown")).unwrap();
    assert_eq!(ga.data, gb.data, "∇W of the final linear must not depend on mask_rc");
    // Upstream gradients flow through ∇X = ∇Y·W^{R,C} ⇒ they MUST differ.
    let ub = mb.grad_dense("tok_emb").unwrap();
    let ua = ma.grad_dense("tok_emb").unwrap();
    let diff = ua.max_abs_diff(&ub);
    assert!(
        diff > 1e-7,
        "upstream gradient identical under different mask_rc ({diff:.3e}) — \
         grad_input is not using W^{{R,C}}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_step_deterministic_across_threads() {
    let (dir, manifest) = setup("threads");
    let steps = 3usize;
    let mut exports: Vec<Store> = Vec::new();
    let mut losses: Vec<Vec<u32>> = Vec::new();
    for threads in [1usize, 4] {
        let policy = ParallelPolicy::with_threads(threads);
        let mut model = HostTrainModel::init(&manifest, 21, policy).unwrap();
        model.lora_init(4).unwrap();
        let mut ls = Vec::new();
        for step in 0..steps {
            let tokens = train_tokens(&manifest, 100 + step as u64);
            let loss = if step < 2 {
                model.train_step(&tokens).unwrap()
            } else {
                model.train_step_lora(&tokens).unwrap()
            };
            ls.push(loss.to_bits());
        }
        losses.push(ls);
        exports.push(export_model(&mut model, true));
    }
    assert_eq!(losses[0], losses[1], "losses must be bit-identical across thread counts");
    let names: Vec<String> =
        exports[0].names().into_iter().map(|s| s.to_string()).collect();
    assert_eq!(
        names,
        exports[1].names().into_iter().map(|s| s.to_string()).collect::<Vec<_>>()
    );
    for name in &names {
        let a = exports[0].read_f32(name).unwrap();
        let b = exports[1].read_f32(name).unwrap();
        let eq = a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(eq, "{name} differs between 1 and 4 threads");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn adapters_start_as_exact_noop_and_training_reduces_loss() {
    let (dir, manifest) = setup("sanity");
    let mut model = HostTrainModel::init(&manifest, 33, ParallelPolicy::with_threads(2))
        .unwrap();
    let tokens = train_tokens(&manifest, 55);
    // Freshly initialized adapters (up = 0) are an exact no-op.
    model.lora_init(8).unwrap();
    let base = model.eval_loss(&tokens, false).unwrap();
    let with = model.eval_loss(&tokens, true).unwrap();
    assert_eq!(base.to_bits(), with.to_bits(), "zero-up adapters must be a no-op");
    // Overfit one batch: the double-pruned step must actually learn.
    let first = model.train_step(&tokens).unwrap();
    let mut last = first;
    for _ in 0..29 {
        last = model.train_step(&tokens).unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(
        last < first - 0.1,
        "30 steps on one batch must reduce the loss ({first:.4} -> {last:.4})"
    );
    // And the lazy phase keeps improving from there.
    let mut lora_last = last;
    for _ in 0..5 {
        lora_last = model.train_step_lora(&tokens).unwrap();
    }
    assert!(lora_last < last + 0.05, "lazy steps must not blow up ({last:.4} -> {lora_last:.4})");
    // The adapters moved off their no-op init.
    let store = export_model(&mut model, true);
    let up = store.read_f32("lora.blocks.0.wqkv_up").unwrap();
    assert!(up.iter().any(|v| *v != 0.0), "up factors must train");
    std::fs::remove_dir_all(&dir).ok();
}
