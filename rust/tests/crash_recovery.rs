//! Crash-safety property suite for the v3 checkpoint format and
//! `--resume` (ISSUE 6 acceptance):
//!
//! * for every injected fault point (torn write, bit-flip, failed
//!   rename) a subsequent recovery either loads the previous valid
//!   checkpoint or fails with a structured error — never a panic, never
//!   a partially-populated [`Store`];
//! * corrupt model/packed files error at every record boundary and under
//!   single-byte flips, while a v2 (pre-checksum) file still loads;
//! * a `--resume`d run is **bitwise identical** to the uninterrupted run
//!   at the same total step count, across thread counts {1, 4}, in both
//!   the sparse-only and lazy-adapter phases;
//! * a corrupted serving checkpoint refuses to open — corrupt weights
//!   are never served.

use slope::backend::ParallelPolicy;
use slope::config::{Method, RunConfig};
use slope::coordinator::checkpoint::{self, CkptError, TrainMeta};
use slope::coordinator::Trainer;
use slope::runtime::Store;
use slope::serve::AotModel;
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::faultfs::{self, FaultPlan};
use slope::util::Rng;
use std::path::PathBuf;

/// Fresh per-test scratch directory (unique tag ⇒ no cross-test races).
fn tmp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("slope_crash_recovery_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small store covering every [`checkpoint::TRAIN_PREFIXES`] plane
/// (f32 and i32 records), parameterized so distinct steps are
/// distinguishable.
fn train_store(v: f32) -> Store {
    let mut s = Store::new();
    s.put_f32("params.a", &[2, 2], &[v, 1.5, -2.0, 3.25]).unwrap();
    s.put_f32("opt.m.a", &[2, 2], &[0.1, 0.2, v, -0.4]).unwrap();
    s.put_i32("opt.t", &[1], &[v as i32]).unwrap();
    s.put_f32("masks.a_r", &[2, 2], &[1.0, 0.0, 0.0, 1.0]).unwrap();
    s.put_f32("lora.a_up", &[2, 1], &[v, -v]).unwrap();
    s.put_f32("lora_opt.m.a_up", &[2, 1], &[0.0, v]).unwrap();
    s
}

fn meta_at(step: usize) -> TrainMeta {
    TrainMeta {
        step,
        steps: 10,
        lazy_fraction: 0.25,
        seed: 42,
        lora_active: step > 5,
        rng: ([step as u64 + 1, 2, 3, 4], None),
    }
}

#[test]
fn every_injected_fault_point_recovers_or_errors_cleanly() {
    let dir = tmp_root("faults");
    let s1 = train_store(1.0);
    checkpoint::save_train_checkpoint(&s1, &meta_at(1), &dir, 16).unwrap();
    let root = dir.join(checkpoint::TRAIN_DIR);
    let step1_file = root.join("step_00000001").join(checkpoint::TRAIN_FILE);
    let file_len = std::fs::metadata(&step1_file).unwrap().len() as usize;
    // Step 2 writes the same plane set, so step 1's record boundaries are
    // exactly the interesting byte offsets of the file about to be torn.
    let boundaries = checkpoint::record_boundaries(&step1_file).unwrap();

    let mut plans = vec![
        FaultPlan { fail_rename: true, ..Default::default() },
        FaultPlan { truncate_at: Some(0), ..Default::default() },
        FaultPlan { bitflip_at: Some(file_len - 1), ..Default::default() },
        FaultPlan { bitflip_at: Some(file_len + 10_000), ..Default::default() },
    ];
    for &b in &boundaries {
        plans.push(FaultPlan { truncate_at: Some(b), ..Default::default() });
        plans.push(FaultPlan { truncate_at: Some(b + 1), ..Default::default() });
        plans.push(FaultPlan { bitflip_at: Some(b), ..Default::default() });
        plans.push(FaultPlan { bitflip_at: Some(b.saturating_sub(2)), ..Default::default() });
    }

    let s2 = train_store(2.0);
    for plan in plans {
        let result = faultfs::with_plan(plan, || {
            checkpoint::save_train_checkpoint(&s2, &meta_at(2), &dir, 16)
        });
        match result {
            Ok(_) => {
                // Only reachable when the fault misses every byte actually
                // written (a flip offset beyond the files): the published
                // checkpoint must then be fully valid.
                let (st, m) = checkpoint::load_train_checkpoint(&dir).unwrap();
                assert_eq!(m, meta_at(2), "plan {plan:?}");
                assert_eq!(st.read_f32("params.a").unwrap(),
                           s2.read_f32("params.a").unwrap());
                // Reset to the step-1-only state for the next plan.
                std::fs::remove_dir_all(root.join("step_00000002")).unwrap();
                std::fs::write(root.join(checkpoint::LATEST_FILE), "step_00000001").unwrap();
            }
            Err(e) => {
                assert!(!root.join("step_00000002").exists(),
                        "plan {plan:?}: failed save must not leave its step dir behind: {e}");
                assert_eq!(
                    std::fs::read_to_string(root.join(checkpoint::LATEST_FILE)).unwrap(),
                    "step_00000001",
                    "plan {plan:?}: LATEST must stay on the previous step"
                );
                let (st, m) = checkpoint::load_train_checkpoint(&dir).unwrap();
                assert_eq!(m, meta_at(1), "plan {plan:?}");
                assert_eq!(st.read_f32("params.a").unwrap(),
                           s1.read_f32("params.a").unwrap(),
                           "plan {plan:?}: recovery must land on the step-1 state exactly");
                assert_eq!(st.read_scalar_i32("opt.t").unwrap(), 1);
            }
        }
    }
}

#[test]
fn corrupt_model_files_error_and_never_populate_the_store() {
    let dir = tmp_root("corrupt_model");
    let mut store = Store::new();
    store.put_f32("params.w", &[2, 3], &[0.5, -1.0, 2.0, 3.5, -4.0, 0.25]).unwrap();
    store.put_i32("params.steps", &[2], &[7, 9]).unwrap();
    store.put_f32("opt.m.w", &[6], &[0.0, 0.1, 0.2, 0.3, 0.4, 0.5]).unwrap();
    let path = dir.join(checkpoint::MODEL_FILE);
    assert_eq!(checkpoint::save(&store, &["params.", "opt."], &path).unwrap(), 3);
    let bytes = std::fs::read(&path).unwrap();
    let boundaries = checkpoint::record_boundaries(&path).unwrap();
    let victim = dir.join("victim.slopeckpt");

    // Truncate at every record boundary, inside the header, and
    // mid-record: all torn shapes must surface a structured error.
    let mut cuts = boundaries.clone();
    cuts.extend([0, 2, 4, 8, 11]);
    cuts.extend(boundaries.iter().map(|b| b + 3));
    cuts.retain(|c| *c < bytes.len());
    for cut in cuts {
        std::fs::write(&victim, &bytes[..cut]).unwrap();
        let mut fresh = Store::new();
        let err = checkpoint::load(&mut fresh, &victim).unwrap_err();
        assert!(err.downcast_ref::<CkptError>().is_some(),
                "cut at {cut}: structured error expected, got: {err}");
        assert!(fresh.names().is_empty(), "cut at {cut}: store must stay empty");
    }

    // One byte-flip per region — magic, version, count, every record,
    // footer tag and footer CRC.  The file checksum catches them all.
    let mut flips = vec![1usize, 5, 9, bytes.len() - 6, bytes.len() - 1];
    flips.extend(boundaries.iter().map(|b| b + 2));
    flips.retain(|f| *f < bytes.len());
    for flip in flips {
        let mut b = bytes.clone();
        b[flip] ^= 0x20;
        std::fs::write(&victim, &b).unwrap();
        let mut fresh = Store::new();
        let err = checkpoint::load(&mut fresh, &victim).unwrap_err();
        assert!(err.downcast_ref::<CkptError>().is_some(),
                "flip at {flip}: structured error expected, got: {err}");
        assert!(fresh.names().is_empty(), "flip at {flip}: store must stay empty");
    }

    // A v2 (pre-checksum) file still loads — with a logged warning only.
    let v2 = dir.join("v2.slopeckpt");
    checkpoint::save_as_v2(&store, &["params.", "opt."], &v2).unwrap();
    let mut fresh = Store::new();
    assert_eq!(checkpoint::load(&mut fresh, &v2).unwrap(), 3);
    assert_eq!(fresh.read_f32("params.w").unwrap(), store.read_f32("params.w").unwrap());
    assert_eq!(fresh.read_f32("opt.m.w").unwrap(), store.read_f32("opt.m.w").unwrap());
}

#[test]
fn corrupt_packed_weight_files_error_cleanly() {
    let dir = tmp_root("corrupt_packed");
    let mut rng = Rng::seed_from_u64(0xBEEF);
    let w = Matrix::randn(8, 16, 1.0, &mut rng);
    let mask = random_row_mask(8, 16, NmScheme::TWO_FOUR, &mut rng);
    let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
    let path = dir.join(checkpoint::PACKED_FILE);
    checkpoint::save_packed_weights(&[("blocks.0.wq", &c)], &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let victim = dir.join("victim.packed.slopeckpt");

    for cut in checkpoint::record_boundaries(&path).unwrap() {
        std::fs::write(&victim, &bytes[..cut]).unwrap();
        assert!(checkpoint::load_packed_weights(&victim).is_err(), "cut at {cut}");
    }
    for flip in [6usize, bytes.len() / 2, bytes.len() - 3] {
        let mut b = bytes.clone();
        b[flip] ^= 0x01;
        std::fs::write(&victim, &b).unwrap();
        assert!(checkpoint::load_packed_weights(&victim).is_err(), "flip at {flip}");
    }
    // The pristine file still round-trips after all of the above.
    let back = checkpoint::load_packed_weights(&path).unwrap();
    assert_eq!(back.len(), 1);
    assert_eq!(back[0].1, c, "values AND packed metadata survive");
}

/// One full train → corrupt-the-newest → resume cycle on the host
/// executor: asserts the resumed continuation is **bitwise identical** to
/// the uninterrupted reference run — final loss bits, every train-state
/// plane, and the meta sidecar (step counter, schedule, RNG state).
fn resume_is_bitwise_identical(tag: &str, threads: usize, steps: usize, lazy: f64) {
    let artifacts = std::env::temp_dir().join("slope_crash_recovery_models");
    let model = format!("cr-{tag}-t{threads}");
    std::fs::remove_dir_all(artifacts.join(&model)).ok();
    let cfg = |ckpt: PathBuf, resume: Option<PathBuf>| RunConfig {
        model: model.clone(),
        method: Method::Slope,
        steps,
        lazy_fraction: lazy,
        eval_every: 2,
        eval_batches: 1,
        seed: 11,
        artifacts: artifacts.clone(),
        out_dir: std::env::temp_dir().join("slope_crash_recovery_runs"),
        checkpoint_dir: Some(ckpt),
        resume,
        keep_checkpoints: 16,
        parallel: ParallelPolicy::with_threads(threads),
    };

    // Uninterrupted reference run.
    let da = tmp_root(&format!("{tag}_t{threads}_ref"));
    let mut a = Trainer::new(cfg(da.clone(), None)).unwrap();
    a.init().unwrap();
    let a_out = a.train().unwrap();

    // Identical run into its own checkpoint dir, then the "crash": its
    // newest training checkpoint is bit-flipped, so recovery must skip it
    // and fall back to the previous step.
    let db = tmp_root(&format!("{tag}_t{threads}_crash"));
    let mut b = Trainer::new(cfg(db.clone(), None)).unwrap();
    b.init().unwrap();
    b.train().unwrap();
    let step_dir = |root: &PathBuf| {
        root.join(checkpoint::TRAIN_DIR).join(format!("step_{steps:08}"))
    };
    let newest = step_dir(&db).join(checkpoint::TRAIN_FILE);
    let mut tampered = std::fs::read(&newest).unwrap();
    let mid = tampered.len() / 2;
    tampered[mid] ^= 0x08;
    std::fs::write(&newest, &tampered).unwrap();
    assert_eq!(checkpoint::peek_train_meta(&db).unwrap().step, steps - 2,
               "{tag} t{threads}: recovery must fall back past the corrupted newest step");

    // Resume restores step T-2 and re-runs the final two steps.
    let mut c = Trainer::new(cfg(db.clone(), Some(db.clone()))).unwrap();
    c.init().unwrap();
    let c_out = c.train().unwrap();

    assert_eq!(c_out.final_loss.to_bits(), a_out.final_loss.to_bits(),
               "{tag} t{threads}: resumed final loss must be bitwise equal \
                ({} vs {})", c_out.final_loss, a_out.final_loss);
    // Checkpoint files are byte-deterministic (records in sorted name
    // order), so whole-file equality IS plane-by-plane bitwise equality —
    // params, compressed-space moments, masks, adapter chain, RNG state.
    for f in [checkpoint::TRAIN_FILE, checkpoint::TRAIN_META_FILE] {
        assert_eq!(std::fs::read(step_dir(&da).join(f)).unwrap(),
                   std::fs::read(step_dir(&db).join(f)).unwrap(),
                   "{tag} t{threads}: {f} must be bitwise identical after resume");
    }

    // A corrupted serving checkpoint must refuse to open: the v3
    // checksums keep corrupt weights out of the serve path entirely.
    let model_file = db.join(checkpoint::MODEL_FILE);
    let mut mb = std::fs::read(&model_file).unwrap();
    let mid = mb.len() / 2;
    mb[mid] ^= 0x40;
    std::fs::write(&model_file, &mb).unwrap();
    assert!(AotModel::open(&db, ParallelPolicy::serial()).is_err(),
            "{tag} t{threads}: a corrupt serving checkpoint must not open");

    std::fs::remove_dir_all(&da).ok();
    std::fs::remove_dir_all(&db).ok();
}

#[test]
fn resume_is_bitwise_identical_sparse_phase() {
    // Sparse-only schedule (λ = 0): checkpoints at steps 0,2,4,6,8;
    // resume falls back to step 6 and re-runs 7..8.
    resume_is_bitwise_identical("sparse", 1, 8, 0.0);
    resume_is_bitwise_identical("sparse", 4, 8, 0.0);
}

#[test]
fn resume_is_bitwise_identical_across_the_lora_flip() {
    // λ = 0.34 over 12 steps flips the lazy adapters on after step 8;
    // the fallback checkpoint (step 10) is inside the lora phase, so the
    // restore must carry the adapter chain and its optimizer state.
    resume_is_bitwise_identical("lora", 1, 12, 0.34);
    resume_is_bitwise_identical("lora", 4, 12, 0.34);
}
