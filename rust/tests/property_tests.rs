//! Property-based tests over the L3 substrates (no artifacts needed).
//!
//! Uses the in-tree seeded property harness (`slope::util::proptest` —
//! DESIGN.md §2 offline substitutions).  Each property runs over dozens of
//! generated cases; failures report a replay seed.

use slope::backend::{gemm, gemm_nt, gemm_tn, lora_fused, lora_naive, prune_and_compress,
                     spmm_rowmajor, spmm_tiled, ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::coordinator::checkpoint;
use slope::data::{Corpus, CorpusSpec};
use slope::runtime::Store;
use slope::sparsity::{double_prune_mask, magnitude_row_mask, random_row_mask, wanda_row_mask,
                      CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::proptest::cases;
use slope::util::Json;

const SCHEMES: [(usize, usize); 4] = [(1, 2), (2, 4), (2, 8), (4, 8)];

#[test]
fn prop_random_masks_satisfy_exact_nm_at_any_shape() {
    cases(40, 0x51, |g| {
        let (n, m) = *g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let rows = g.usize_in(1, 24);
        let cols = g.dim_multiple_of(m, 12);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        assert!(mask.check_row_nm(s));
        assert!((mask.density() - s.density()).abs() < 1e-9);
    });
}

#[test]
fn prop_double_prune_subset_colwise_nm_and_density_drop() {
    cases(30, 0x52, |g| {
        let (n, m) = *g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let dim = g.dim_multiple_of(m, 6).max(m * 2);
        let w = Matrix::randn(dim, dim, 1.0, &mut g.rng);
        let mr = random_row_mask(dim, dim, s, &mut g.rng);
        let mrc = double_prune_mask(&w, &mr, s);
        for i in 0..mr.keep.len() {
            assert!(!mrc.keep[i] || mr.keep[i], "only removes");
        }
        assert!(mrc.density() <= mr.density() + 1e-12);
        // Column groups obey N:M.
        assert!(mrc.check_col_nm(s));
    });
}

#[test]
fn prop_compress_roundtrip_and_inplace_update() {
    cases(30, 0x53, |g| {
        let (n, m) = *g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let rows = g.usize_in(1, 16);
        let cols = g.dim_multiple_of(m, 8);
        let w = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        // Mix mask sources: random and magnitude.
        let mask = if g.rng.chance(0.5) {
            random_row_mask(rows, cols, s, &mut g.rng)
        } else {
            magnitude_row_mask(&w, s)
        };
        let mut c = CompressedNm::compress(&w, &mask, s);
        assert_eq!(c.decompress(), mask.apply(&w));
        let w2 = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        c.update_from_dense(&w2);
        assert_eq!(c.decompress(), mask.apply(&w2));
        // Decoded indices strictly increasing per group (packed layout).
        for r in 0..rows {
            for grp in 0..cols / m {
                for i in 1..n {
                    assert!(c.index(r, grp * n + i - 1) < c.index(r, grp * n + i));
                }
            }
        }
    });
}

#[test]
fn prop_spmm_equals_masked_gemm_all_algos() {
    cases(25, 0x54, |g| {
        let (n, m) = *g.pick(&SCHEMES);
        let s = NmScheme::new(n, m);
        let b = g.usize_in(1, 12);
        let d_in = g.dim_multiple_of(m, 8);
        let d_out = g.usize_in(1, 24);
        let x = Matrix::randn(b, d_in, 1.0, &mut g.rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut g.rng);
        let mask = random_row_mask(d_out, d_in, s, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, s);
        let want = gemm_nt(&x, &mask.apply(&w));
        assert!(spmm_rowmajor(&x, &c).max_abs_diff(&want) < 1e-3);
        let tile = g.usize_in(1, 40);
        assert!(spmm_tiled(&x, &c, tile).max_abs_diff(&want) < 1e-3);
    });
}

#[test]
fn prop_backend_eq456_contract() {
    // The full Algorithm-1 contract at random shapes: fwd uses W^R, grad-x
    // uses W^{R,C}, grad-w is masked to the static support.
    cases(20, 0x55, |g| {
        let b = g.usize_in(1, 8);
        let d_in = g.dim_multiple_of(4, 8).max(8);
        let d_out = g.dim_multiple_of(4, 6).max(8);
        let x = Matrix::randn(b, d_in, 1.0, &mut g.rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut g.rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut g.rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::serial());
        let gy = Matrix::randn(b, d_out, 1.0, &mut g.rng);

        let y = be.forward(&x);
        assert!(y.max_abs_diff(&gemm_nt(&x, &be.mask_r.apply(&w))) < 1e-3);

        let gx = be.grad_input(&gy);
        assert!(gx.max_abs_diff(&gemm(&gy, &be.mask_rc.apply(&w))) < 1e-3);

        let gw = be.grad_weight(&gy, &x);
        let dense_gw = gemm_tn(&gy, &x);
        assert!(gw.decompress().max_abs_diff(&be.mask_r.apply(&dense_gw)) < 1e-3);
    });
}

#[test]
fn prop_lora_fusion_equivalence() {
    cases(20, 0x56, |g| {
        let b = g.usize_in(1, 10);
        let d_in = g.dim_multiple_of(4, 8).max(8);
        let d_out = g.dim_multiple_of(4, 8).max(8);
        let r = g.usize_in(1, 9);
        let x = Matrix::randn(b, d_in, 1.0, &mut g.rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut g.rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let lo_up = Matrix::randn(d_out, r, 0.5, &mut g.rng);
        let lo_down = Matrix::randn(r, d_in, 0.5, &mut g.rng);
        let p = ParallelPolicy::serial();
        let a = lora_naive(&x, &c, &lo_up, &lo_down, SpmmAlgo::RowMajor, &p);
        let f = lora_fused(&x, &c, &lo_up, &lo_down, SpmmAlgo::RowMajor, &p);
        assert!(a.max_abs_diff(&f) < 1e-3);
    });
}

#[test]
fn prop_prune_and_compress_is_gather() {
    cases(20, 0x57, |g| {
        let rows = g.usize_in(1, 12);
        let cols = g.dim_multiple_of(4, 8);
        let w = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let mask = random_row_mask(rows, cols, NmScheme::TWO_FOUR, &mut g.rng);
        let pattern = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let grad = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let packed = prune_and_compress(&grad, &pattern);
        assert_eq!(packed.decompress(), mask.apply(&grad));
    });
}

#[test]
fn prop_wanda_scores_monotone_in_activation_norm() {
    cases(20, 0x58, |g| {
        let cols = g.dim_multiple_of(4, 6);
        let w = Matrix::randn(4, cols, 1.0, &mut g.rng);
        // Huge norm on a random column forces it to be kept in its group.
        let star = g.usize_in(0, cols);
        let mut norms = vec![1.0f32; cols];
        norms[star] = 1e6;
        let mask = wanda_row_mask(&w, &norms, NmScheme::TWO_FOUR);
        for r in 0..4 {
            assert!(mask.at(r, star), "boosted column must survive");
        }
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    cases(40, 0x59, |g| {
        // Build a random JSON document and round-trip it.
        fn build(g: &mut slope::util::proptest::Gen, depth: usize) -> Json {
            match if depth > 2 { g.usize_in(0, 4) } else { g.usize_in(0, 6) } {
                0 => Json::Null,
                1 => Json::Bool(g.rng.chance(0.5)),
                2 => Json::Num((g.rng.normal() * 100.0 * 8.0).round() / 8.0),
                3 => {
                    let n = g.usize_in(0, 999);
                    Json::Str(format!("s{}-\"q\"\\n{}", g.case, n))
                }
                4 => Json::Str("unicode é λ 🤖".into()),
                5 => Json::Arr((0..g.usize_in(0, 4)).map(|_| build(g, depth + 1)).collect()),
                _ => Json::Obj((0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), build(g, depth + 1)))
                    .collect()),
            }
        }
        let doc = build(g, 0);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(doc, back, "{text}");
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_stores() {
    cases(12, 0x5A, |g| {
        let mut store = Store::new();
        let mut names = vec![];
        for i in 0..g.usize_in(1, 6) {
            let rows = g.usize_in(1, 8);
            let cols = g.usize_in(1, 8);
            let name = format!("params.t{i}");
            let data = g.f32_vec(rows * cols, 1.0);
            store.put_f32(&name, &[rows, cols], &data).unwrap();
            names.push((name, data));
        }
        store.put_i32("tokens", &[3], &[1, 2, 3]).unwrap();
        let path = std::env::temp_dir().join(format!("slope_prop_{}.ckpt", g.case));
        let n = checkpoint::save(&store, &["params."], &path).unwrap();
        assert_eq!(n, names.len());
        let mut fresh = Store::new();
        checkpoint::load(&mut fresh, &path).unwrap();
        for (name, data) in names {
            assert_eq!(fresh.read_f32(&name).unwrap(), data);
        }
        assert!(!fresh.contains("tokens"), "prefix filter must exclude tokens");
        std::fs::remove_file(path).ok();
    });
}

#[test]
fn prop_corpus_batches_always_in_bounds() {
    cases(8, 0x5B, |g| {
        let vocab = 8 * g.usize_in(4, 64);
        let corpus = Corpus::generate(CorpusSpec {
            train_tokens: 6000,
            val_tokens: 3000,
            ..CorpusSpec::for_vocab(vocab, g.case as u64)
        });
        let b = g.usize_in(1, 6);
        let s = g.usize_in(4, 48);
        let batch = corpus.train_batch(b, s, &mut g.rng);
        assert_eq!(batch.tokens.len(), b * (s + 1));
        assert!(batch.tokens.iter().all(|t| (*t as usize) < vocab && *t >= 0));
        let (cz, answers) = corpus.cloze_batch(b, s.max(8), g.usize_in(0, 5));
        assert_eq!(answers.len(), b);
        assert!(cz.tokens.iter().all(|t| (*t as usize) < vocab));
        // Every answer follows the grammar for the final context token.
        let sl = s.max(8);
        for row in 0..b {
            let last = cz.tokens[row * sl + sl - 1] as usize;
            let a = answers[row] as u32;
            assert!(corpus.sigma[0][last] == a || corpus.sigma[1][last] == a);
        }
    });
}

#[test]
fn prop_mask_hamming_metric_properties() {
    cases(20, 0x5C, |g| {
        let rows = g.usize_in(1, 8);
        let cols = g.dim_multiple_of(4, 6);
        let s = NmScheme::TWO_FOUR;
        let a = random_row_mask(rows, cols, s, &mut g.rng);
        let b = random_row_mask(rows, cols, s, &mut g.rng);
        // Identity, symmetry, bounds.
        assert_eq!(a.hamming(&a), 0);
        assert_eq!(a.hamming(&b), b.hamming(&a));
        assert!(a.hamming(&b) <= rows * cols);
    });
}
