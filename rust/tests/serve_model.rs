//! Integration suites for the `ServeModel` redesign:
//!
//! * `AotModel` serves a checkpointed transformer end-to-end (synthetic
//!   artifact → restore with packed v2 planes → coalesced batches →
//!   next-token logits), and its outputs match an **independent** dense
//!   reference implementation of the python model — plain nested loops,
//!   no kernel engine, no `CompressedNm`;
//! * packed-plane restores are bit-identical to re-compression restores;
//! * coalescing is invisible in payloads: engine batches of any fill
//!   reproduce the direct full-batch forward;
//! * when real artifacts exist (`make artifacts` + real xla-rs), the
//!   host executor is pinned against the AOT `forward` executable itself
//!   (`Session::run`) — the cross-implementation parity the offline stub
//!   cannot check;
//! * the async admission front-end: N concurrent producers receive
//!   exactly the answers serial submission gives, bit-for-bit.

use slope::backend::{ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::coordinator::checkpoint;
use slope::runtime::{write_synthetic_artifact, Manifest, Session, Store, SynthSpec};
use slope::serve::{Admission, AotModel, AotPath, BatchPolicy, LoraAdapter, ServeEngine,
                   ServeLayer, ServeModel};
use slope::sparsity::{random_row_mask, NmScheme};
use slope::tensor::Matrix;
use slope::util::Rng;
use std::path::Path;
use std::time::Duration;

// ---- an independent dense reference of python/compile/model.py --------

/// Dense weights + biases for one block, masks already applied.
struct RefBlock {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    /// (w_masked, bias, lora_up, lora_down) per linear, qkv/proj/up/down.
    lins: Vec<(Matrix, Vec<f32>, Option<(Matrix, Matrix)>)>,
}

struct RefModel {
    n_head: usize,
    seq_len: usize,
    vocab: usize,
    d: usize,
    tok_emb: Matrix,
    pos_emb: Matrix,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    blocks: Vec<RefBlock>,
}

fn ref_from_store(m: &Manifest, store: &Store) -> RefModel {
    let read = |n: &str| store.read_matrix(n).unwrap();
    let readv = |n: &str| store.read_f32(n).unwrap();
    let mut blocks = vec![];
    for i in 0..m.config.n_layer {
        let mut lins = vec![];
        for wname in ["wqkv", "wproj", "wup", "wdown"] {
            let bname = format!("b{}", &wname[1..]);
            let w = read(&format!("params.blocks.{i}.{wname}"));
            let mask = read(&format!("masks.blocks.{i}.{wname}_r"));
            let wm = w.hadamard(&mask);
            let bias = readv(&format!("params.blocks.{i}.{bname}"));
            let dn = format!("lora.blocks.{i}.{wname}_down");
            let un = format!("lora.blocks.{i}.{wname}_up");
            let lora = if store.contains(&dn) {
                Some((read(&un), read(&dn)))
            } else {
                None
            };
            lins.push((wm, bias, lora));
        }
        blocks.push(RefBlock {
            ln1_g: readv(&format!("params.blocks.{i}.ln1_g")),
            ln1_b: readv(&format!("params.blocks.{i}.ln1_b")),
            ln2_g: readv(&format!("params.blocks.{i}.ln2_g")),
            ln2_b: readv(&format!("params.blocks.{i}.ln2_b")),
            lins,
        });
    }
    RefModel {
        n_head: m.config.n_head,
        seq_len: m.config.seq_len,
        vocab: m.config.vocab_size,
        d: m.config.d_model,
        tok_emb: read("params.tok_emb"),
        pos_emb: read("params.pos_emb"),
        lnf_g: readv("params.lnf_g"),
        lnf_b: readv("params.lnf_b"),
        blocks,
    }
}

fn ref_layer_norm(x: &[f32], g: &[f32], b: &[f32]) -> Vec<f32> {
    let n = x.len() as f32;
    let mu = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    x.iter().enumerate().map(|(j, v)| (v - mu) * inv * g[j] + b[j]).collect()
}

/// `y = x · Wᵀ + x·Rᵀ·Lᵀ + b` for one activation row, triple loops.
fn ref_linear(x: &[f32], w: &Matrix, bias: &[f32],
              lora: &Option<(Matrix, Matrix)>) -> Vec<f32> {
    let mut y: Vec<f32> = (0..w.rows)
        .map(|o| w.row(o).iter().zip(x).map(|(a, b)| a * b).sum::<f32>() + bias[o])
        .collect();
    if let Some((up, down)) = lora {
        let t: Vec<f32> = (0..down.rows)
            .map(|r| down.row(r).iter().zip(x).map(|(a, b)| a * b).sum::<f32>())
            .collect();
        for (o, yo) in y.iter_mut().enumerate() {
            *yo += up.row(o).iter().zip(&t).map(|(a, b)| a * b).sum::<f32>();
        }
    }
    y
}

fn ref_gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// Last-position logits for one token sequence — the reference the
/// `AotModel` outputs are pinned against.
fn ref_forward_last(model: &RefModel, tokens: &[i32]) -> Vec<f32> {
    let (s, d, nh) = (model.seq_len, model.d, model.n_head);
    let hd = d / nh;
    let mut h: Vec<Vec<f32>> = (0..s)
        .map(|t| {
            let te = model.tok_emb.row(tokens[t] as usize);
            let pe = model.pos_emb.row(t);
            (0..d).map(|j| te[j] + pe[j]).collect()
        })
        .collect();
    for blk in &model.blocks {
        // Attention sub-block.
        let qkv: Vec<Vec<f32>> = h
            .iter()
            .map(|row| {
                let n = ref_layer_norm(row, &blk.ln1_g, &blk.ln1_b);
                ref_linear(&n, &blk.lins[0].0, &blk.lins[0].1, &blk.lins[0].2)
            })
            .collect();
        let mut att = vec![vec![0.0f32; d]; s];
        for head in 0..nh {
            let (qo, ko, vo) = (head * hd, d + head * hd, 2 * d + head * hd);
            for q in 0..s {
                let mut scores: Vec<f32> = (0..=q)
                    .map(|t| {
                        (0..hd).map(|j| qkv[q][qo + j] * qkv[t][ko + j]).sum::<f32>()
                            / (hd as f32).sqrt()
                    })
                    .collect();
                let maxv = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut denom = 0.0;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxv).exp();
                    denom += *sc;
                }
                for (t, sc) in scores.iter().enumerate() {
                    let w = sc / denom;
                    for j in 0..hd {
                        att[q][qo + j] += w * qkv[t][vo + j];
                    }
                }
            }
        }
        for (row, a) in h.iter_mut().zip(&att) {
            let proj = ref_linear(a, &blk.lins[1].0, &blk.lins[1].1, &blk.lins[1].2);
            for (x, p) in row.iter_mut().zip(&proj) {
                *x += p;
            }
        }
        // MLP sub-block.
        for row in h.iter_mut() {
            let n = ref_layer_norm(row, &blk.ln2_g, &blk.ln2_b);
            let mut up = ref_linear(&n, &blk.lins[2].0, &blk.lins[2].1, &blk.lins[2].2);
            for v in up.iter_mut() {
                *v = ref_gelu(*v);
            }
            let down = ref_linear(&up, &blk.lins[3].0, &blk.lins[3].1, &blk.lins[3].2);
            for (x, dv) in row.iter_mut().zip(&down) {
                *x += dv;
            }
        }
    }
    let last = ref_layer_norm(&h[s - 1], &model.lnf_g, &model.lnf_b);
    (0..model.vocab)
        .map(|o| model.tok_emb.row(o).iter().zip(&last).map(|(a, b)| a * b).sum())
        .collect()
}

fn synth_dir(tag: &str, seed: u64) -> (std::path::PathBuf, SynthSpec) {
    let dir = std::env::temp_dir().join(format!("slope_serve_model_{tag}"));
    let spec = SynthSpec { seed, ..SynthSpec::default() };
    write_synthetic_artifact(&dir, &spec).unwrap();
    (dir, spec)
}

fn random_tokens(n: usize, vocab: usize, rng: &mut Rng) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

// ---- AotModel end-to-end ----------------------------------------------

#[test]
fn aot_model_matches_independent_dense_reference() {
    let (dir, spec) = synth_dir("refparity", 21);
    let manifest = Manifest::load(&dir).unwrap();
    let (store, _) = checkpoint::load_model_checkpoint(&dir).unwrap();
    let reference = ref_from_store(&manifest, &store);

    let model = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
    assert_eq!(model.path(), AotPath::HostKernels);
    let mut eng = ServeEngine::with_model(
        model,
        BatchPolicy::new(4, Duration::from_millis(1)),
    )
    .unwrap();

    let mut rng = Rng::seed_from_u64(0xCAFE);
    let k = 6;
    let seqs: Vec<Vec<i32>> =
        (0..k).map(|_| random_tokens(spec.seq_len, spec.vocab, &mut rng)).collect();
    for seq in &seqs {
        eng.submit(AotModel::encode_tokens(seq), Duration::ZERO).unwrap();
    }
    let mut got = eng.flush(Duration::ZERO).unwrap();
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), k);
    for (i, resp) in got.iter().enumerate() {
        let want = ref_forward_last(&reference, &seqs[i]);
        assert_eq!(resp.output.len(), want.len(), "request {i}");
        let max_diff = resp
            .output
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 2e-3,
            "request {i}: engine output diverges from the dense reference ({max_diff})"
        );
    }
    let s = eng.stats().summary();
    assert_eq!(s.served, k);
    assert!(s.batches >= 2, "fill 4 + 2 under max_batch 4");
    // Malformed payloads are rejected per-request at submit — they can
    // never poison a coalesced batch of well-formed neighbours.
    assert!(
        eng.submit(vec![spec.vocab as f32; spec.seq_len], Duration::ZERO).is_err(),
        "out-of-vocab token must be rejected at submit"
    );
    assert_eq!(eng.pending(), 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn packed_plane_restore_is_bit_identical_to_recompression() {
    let (dir, spec) = synth_dir("packedparity", 22);
    let mut rng = Rng::seed_from_u64(1);
    let seq = random_tokens(spec.seq_len, spec.vocab, &mut rng);
    let x = Matrix::from_vec(1, spec.seq_len, AotModel::encode_tokens(&seq));

    // Restore WITH the packed planes.
    let mut with_packed = AotModel::open(&dir, ParallelPolicy::serial()).unwrap();
    assert_eq!(with_packed.packed_restored(), 7);
    let mut y_packed = Matrix::zeros(0, 0);
    with_packed.forward_batch_into(&x, &mut y_packed).unwrap();

    // Delete the packed file: restore must fall back to re-compression
    // and produce the exact same operands, hence identical outputs.
    std::fs::remove_file(dir.join(checkpoint::PACKED_FILE)).unwrap();
    let mut recompressed = AotModel::open(&dir, ParallelPolicy::serial()).unwrap();
    assert_eq!(recompressed.packed_restored(), 0);
    let mut y_re = Matrix::zeros(0, 0);
    recompressed.forward_batch_into(&x, &mut y_re).unwrap();

    assert_eq!(y_packed.data, y_re.data,
               "packed-plane restore must be bit-identical to re-compression");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_coalescing_is_invisible_in_payloads() {
    let (dir, spec) = synth_dir("fillparity", 23);
    let mut rng = Rng::seed_from_u64(2);
    let k = 5;
    let seqs: Vec<Vec<i32>> =
        (0..k).map(|_| random_tokens(spec.seq_len, spec.vocab, &mut rng)).collect();

    // Direct full-batch forward through the trait.
    let mut direct = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
    let mut x = Matrix::zeros(k, spec.seq_len);
    for (r, seq) in seqs.iter().enumerate() {
        x.row_mut(r).copy_from_slice(&AotModel::encode_tokens(seq));
    }
    let mut want = Matrix::zeros(0, 0);
    direct.forward_batch_into(&x, &mut want).unwrap();

    // Engine-coalesced fills 2+2+1.
    let model = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
    let mut eng =
        ServeEngine::with_model(model, BatchPolicy::new(2, Duration::from_millis(1))).unwrap();
    for seq in &seqs {
        eng.submit(AotModel::encode_tokens(seq), Duration::ZERO).unwrap();
    }
    let mut got = eng.flush(Duration::ZERO).unwrap();
    got.sort_by_key(|r| r.id);
    for (r, resp) in got.iter().enumerate() {
        assert_eq!(resp.output.as_slice(), want.row(r),
                   "row {r}: batch fill must not change the payload");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Cross-implementation parity against the AOT executable itself.
/// Requires `make artifacts` + real xla-rs, so it skips (like the other
/// artifact-gated integration tests) in the offline environment; when it
/// runs, the host kernel executor's checkpoint restore is pinned against
/// `Session::run("forward")` on identical state.
#[test]
fn aot_host_executor_matches_session_forward_when_artifacts_exist() {
    const CFG: &str = "artifacts/gpt-nano-half-depth";
    if !Path::new(CFG).exists() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return;
    }
    let h = Session::open_cached(Path::new(CFG)).expect("open session");
    let mut store = Store::new();
    store.put_scalar_i32("seed", 17);
    if h.borrow_mut().run("init", &mut store).is_err() {
        eprintln!("skipping: PJRT execution unavailable (offline xla stub)");
        return;
    }
    let manifest = h.borrow().manifest.clone();
    let c = manifest.config.clone();

    // Checkpoint the initialized model into a serving directory (no HLO
    // files there, so AotModel falls back to the host executor).
    let dir = std::env::temp_dir().join("slope_serve_model_sessionparity");
    std::fs::create_dir_all(&dir).unwrap();
    checkpoint::save_model_checkpoint(&store, &manifest, &dir).unwrap();
    std::fs::copy(Path::new(CFG).join("manifest.json"), dir.join("manifest.json")).unwrap();

    let mut rng = Rng::seed_from_u64(41);
    let toks = random_tokens(c.batch_size * c.seq_len, c.vocab_size, &mut rng);
    store.put_i32("tokens", &[c.batch_size, c.seq_len], &toks).unwrap();
    h.borrow_mut().run("forward", &mut store).expect("session forward");
    let logits = store.read_f32("logits").unwrap();

    let mut model = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
    assert_eq!(model.path(), AotPath::HostKernels);
    let mut x = Matrix::zeros(c.batch_size, c.seq_len);
    for r in 0..c.batch_size {
        let row: Vec<f32> =
            toks[r * c.seq_len..(r + 1) * c.seq_len].iter().map(|t| *t as f32).collect();
        x.row_mut(r).copy_from_slice(&row);
    }
    let mut y = Matrix::zeros(0, 0);
    model.forward_batch_into(&x, &mut y).unwrap();
    for r in 0..c.batch_size {
        let off = (r * c.seq_len + (c.seq_len - 1)) * c.vocab_size;
        let want = &logits[off..off + c.vocab_size];
        let max_diff = y
            .row(r)
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "row {r}: host executor vs Session::run ({max_diff})");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---- async admission ---------------------------------------------------

fn stack_engine(seed: u64) -> slope::Result<ServeEngine> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut layers = Vec::new();
    let mut d_in = 16;
    for d_out in [24usize, 16] {
        let w = Matrix::randn(d_out, d_in, 1.0, &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::with_threads(2));
        let lora = LoraAdapter {
            up: Matrix::randn(d_out, 4, 0.2, &mut rng),
            down: Matrix::randn(4, d_in, 0.2, &mut rng),
        };
        layers.push(ServeLayer::new(be, Some(lora))?);
        d_in = d_out;
    }
    ServeEngine::new(layers, BatchPolicy::new(4, Duration::from_micros(200)))
}

#[test]
fn concurrent_producers_get_the_serial_answers() {
    const MODEL_SEED: u64 = 0x5EED;
    let n_inputs = 32usize;
    let producers = 4usize;
    let mut rng = Rng::seed_from_u64(77);
    let inputs: Vec<Vec<f32>> = (0..n_inputs)
        .map(|_| (0..16).map(|_| rng.normal_f32(1.0)).collect())
        .collect();

    // Serial ground truth: one engine, one submitter, full flush.
    let mut serial = stack_engine(MODEL_SEED).unwrap();
    let mut want: Vec<Vec<f32>> = Vec::with_capacity(n_inputs);
    for input in &inputs {
        serial.submit(input.clone(), Duration::ZERO).unwrap();
    }
    let mut responses = serial.flush(Duration::ZERO).unwrap();
    responses.sort_by_key(|r| r.id);
    for r in responses {
        want.push(r.output);
    }

    // Concurrent: N producers over the admission front-end, same model
    // seed, arbitrary interleaving/coalescing.
    let adm = Admission::spawn(move || stack_engine(MODEL_SEED),
                               Duration::from_micros(100));
    let mut handles = Vec::new();
    for p in 0..producers {
        let client = adm.client();
        let quota = n_inputs / producers;
        let my_inputs: Vec<(u64, Vec<f32>)> = (0..quota)
            .map(|i| {
                let global = p * quota + i;
                (global as u64, inputs[global].clone())
            })
            .collect();
        handles.push(std::thread::spawn(move || -> Vec<(u64, Vec<f32>)> {
            for (tag, input) in &my_inputs {
                client.submit(*tag, input.clone()).unwrap();
            }
            (0..my_inputs.len())
                .map(|_| {
                    let (tag, resp) = client.recv().unwrap();
                    (tag, resp.output)
                })
                .collect()
        }));
    }
    let mut got: Vec<(u64, Vec<f32>)> = Vec::new();
    for h in handles {
        got.extend(h.join().expect("producer thread"));
    }
    assert_eq!(got.len(), n_inputs);
    got.sort_by_key(|(tag, _)| *tag);
    for (tag, output) in got {
        assert_eq!(output, want[tag as usize],
                   "request {tag}: concurrent admission changed the payload");
    }
    let stats = adm.finish().unwrap();
    assert_eq!(stats.served, n_inputs);
    assert!(stats.p99_ms >= stats.p50_ms);
}
