//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! Require `make artifacts` (the smallest config, `gpt-nano-half-depth`,
//! keeps XLA compile times low).  These tests exercise the python→rust
//! contract end-to-end: manifest schema, init, the Eq. 4–6 train step,
//! mask-support invariants, determinism, and checkpoint round-trips.

use slope::coordinator::checkpoint;
use slope::runtime::{Session, Store};
use std::path::Path;

const CFG: &str = "artifacts/gpt-nano-half-depth";

fn artifacts_present() -> bool {
    Path::new(CFG).exists()
}

/// Skip (early-return) when `make artifacts` has not been run — these
/// tests exercise the python→rust AOT contract, which needs the HLO set.
macro_rules! require_artifacts {
    () => {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts (run `make artifacts` first)");
            return;
        }
    };
}

fn session() -> slope::runtime::SessionHandle {
    Session::open_cached(Path::new(CFG)).expect("open session")
}

fn init_store(seed: i32) -> (slope::runtime::SessionHandle, Store) {
    let h = session();
    let mut store = Store::new();
    store.put_scalar_i32("seed", seed);
    h.borrow_mut().run("init", &mut store).expect("init");
    (h, store)
}

fn tokens_for(store: &mut Store, b: usize, s1: usize, seed: u64) {
    let mut rng = slope::util::Rng::seed_from_u64(seed);
    let toks: Vec<i32> = (0..b * s1).map(|_| rng.below(512) as i32).collect();
    store.put_i32("tokens", &[b, s1], &toks).unwrap();
}

#[test]
fn manifest_contract() {
    require_artifacts!();
    let h = session();
    let sess = h.borrow();
    let m = &sess.manifest;
    assert_eq!(m.config.name, "gpt-nano-half-depth");
    for name in ["init", "train_step", "lora_init", "train_step_lora",
                 "eval_step", "forward"] {
        let e = m.exe(name).expect(name);
        assert!(!e.inputs.is_empty() || name == "init");
        assert!(!e.outputs.is_empty());
        assert!(m.hlo_path(name).unwrap().exists(), "{name} HLO file missing");
    }
    // Train step state round-trip: every params.*/opt.* output has a
    // matching input with identical shape.
    let ts = m.exe("train_step").unwrap();
    for out in &ts.outputs {
        if out.name.starts_with("params.") || out.name.starts_with("opt.") {
            let inp = ts.inputs.iter().find(|i| i.name == out.name)
                .unwrap_or_else(|| panic!("no input for output {}", out.name));
            assert_eq!(inp.shape, out.shape, "{}", out.name);
            assert_eq!(inp.dtype, out.dtype, "{}", out.name);
        }
    }
}

#[test]
fn init_produces_nm_masks_and_finite_params() {
    require_artifacts!();
    let (_h, store) = init_store(7);
    // Block-1 wup row mask must be exactly 2:4 along d_in.
    let mask = store.read_f32("masks.blocks.1.wup_r").unwrap();
    let d_in = 128;
    for group in mask.chunks(4) {
        let kept: f32 = group.iter().sum();
        assert_eq!(kept, 2.0, "2:4 violated");
    }
    let _ = d_in;
    // Double-pruned mask is a subset.
    let mrc = store.read_f32("masks.blocks.1.wup_rc").unwrap();
    for (r, rc) in mask.iter().zip(&mrc) {
        assert!(*rc <= *r, "RC mask must be subset of R mask");
    }
    // Params finite.
    let w = store.read_f32("params.blocks.1.wup").unwrap();
    assert!(w.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_decreases_loss_and_respects_support() {
    require_artifacts!();
    let (h, mut store) = init_store(1);
    let (b, s1) = h.borrow().manifest.train_tokens_shape();
    let mut losses = vec![];
    for i in 0..4 {
        tokens_for(&mut store, b, s1, 100 + i); // fixed pool of batches
        h.borrow_mut().run("train_step", &mut store).unwrap();
        losses.push(store.read_scalar_f32("loss").unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
    // Pruned slots must be exactly zero after updates (Algorithm 1, 17–18).
    let w = store.read_f32("params.blocks.1.wup").unwrap();
    let mask = store.read_f32("masks.blocks.1.wup_r").unwrap();
    for (wv, mv) in w.iter().zip(&mask) {
        if *mv == 0.0 {
            assert_eq!(*wv, 0.0, "update leaked outside the static mask");
        }
    }
    // Optimizer moments stay inside the support too.
    let m = store.read_f32("opt.m.blocks.1.wup").unwrap();
    for (mvv, mv) in m.iter().zip(&mask) {
        if *mv == 0.0 {
            assert_eq!(*mvv, 0.0, "Adam moment leaked outside the mask");
        }
    }
}

#[test]
fn lora_init_is_noop_then_trains() {
    require_artifacts!();
    let (h, mut store) = init_store(2);
    let (b, s1) = h.borrow().manifest.train_tokens_shape();
    // Eval before adapters.
    tokens_for(&mut store, b, s1, 55);
    h.borrow_mut().run("eval_step", &mut store).unwrap();
    let base = store.read_scalar_f32("loss").unwrap();
    // Adapters initialized (up factor = 0) must not change the function.
    store.put_scalar_i32("seed", 99);
    h.borrow_mut().run("lora_init", &mut store).unwrap();
    h.borrow_mut().run("eval_step_lora", &mut store).unwrap();
    let with_lora = store.read_scalar_f32("loss").unwrap();
    assert!((base - with_lora).abs() < 1e-4, "{base} vs {with_lora}");
    // One adapter step moves the up factors off zero.
    h.borrow_mut().run("train_step_lora", &mut store).unwrap();
    let up = store.read_f32("lora.blocks.0.wup_up").unwrap();
    assert!(up.iter().any(|v| *v != 0.0), "adapters did not train");
}

#[test]
fn eval_is_deterministic() {
    require_artifacts!();
    let (h, mut store) = init_store(3);
    let (b, s1) = h.borrow().manifest.train_tokens_shape();
    tokens_for(&mut store, b, s1, 77);
    h.borrow_mut().run("eval_step", &mut store).unwrap();
    let a = store.read_scalar_f32("loss").unwrap();
    h.borrow_mut().run("eval_step", &mut store).unwrap();
    let b2 = store.read_scalar_f32("loss").unwrap();
    assert_eq!(a, b2, "same inputs must give identical loss");
}

#[test]
fn same_seed_same_init_different_seed_different_masks() {
    require_artifacts!();
    let (_h, s1) = init_store(11);
    let (_h2, s2) = init_store(11);
    assert_eq!(
        s1.read_f32("params.blocks.0.wqkv").unwrap(),
        s2.read_f32("params.blocks.0.wqkv").unwrap()
    );
    let (_h3, s3) = init_store(12);
    assert_ne!(
        s1.read_f32("masks.blocks.1.wup_r").unwrap(),
        s3.read_f32("masks.blocks.1.wup_r").unwrap()
    );
}

#[test]
fn checkpoint_roundtrip_through_store() {
    require_artifacts!();
    let (h, mut store) = init_store(4);
    let (b, s1) = h.borrow().manifest.train_tokens_shape();
    tokens_for(&mut store, b, s1, 5);
    h.borrow_mut().run("train_step", &mut store).unwrap();

    let tmp = std::env::temp_dir().join("slope_integration.slopeckpt");
    let n = checkpoint::save(&store, &["params.", "masks."], &tmp).unwrap();
    assert!(n > 20);

    // Restore into a freshly-initialized store and verify eval parity.
    let (_h2, mut fresh) = init_store(999);
    checkpoint::load(&mut fresh, &tmp).unwrap();
    tokens_for(&mut store, b, s1, 123);
    tokens_for(&mut fresh, b, s1, 123);
    h.borrow_mut().run("eval_step", &mut store).unwrap();
    let a = store.read_scalar_f32("loss").unwrap();
    h.borrow_mut().run("eval_step", &mut fresh).unwrap();
    let b2 = fresh.read_scalar_f32("loss").unwrap();
    assert!((a - b2).abs() < 1e-6, "checkpoint restore changed the model: {a} vs {b2}");
    std::fs::remove_file(tmp).ok();
}

#[test]
fn forward_logits_shape_and_finiteness() {
    require_artifacts!();
    let (h, mut store) = init_store(5);
    let c = h.borrow().manifest.config.clone();
    let mut rng = slope::util::Rng::seed_from_u64(9);
    let toks: Vec<i32> = (0..c.batch_size * c.seq_len)
        .map(|_| rng.below(c.vocab_size) as i32)
        .collect();
    store.put_i32("tokens", &[c.batch_size, c.seq_len], &toks).unwrap();
    h.borrow_mut().run("forward", &mut store).unwrap();
    let logits = store.read_f32("logits").unwrap();
    assert_eq!(logits.len(), c.batch_size * c.seq_len * c.vocab_size);
    assert!(logits.iter().all(|v| v.is_finite()));
}
