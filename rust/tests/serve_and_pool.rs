//! Integration suites for the persistent worker pool and the serving
//! subsystem:
//!
//! * column-partitioned SpMM/GEMM is **bit-identical** to serial across
//!   worker counts {1, 2, 4, 7} and ragged shapes — including the
//!   `batch = 1` serving shape the column split exists for;
//! * the pool is truly persistent: ≥ 1000 parallel regions reuse the
//!   same parked workers without spawning a single new thread (pinned
//!   via the engine's spawn counter);
//! * `ServeEngine` coalescing honors `max_batch` and `max_wait`, and its
//!   outputs match a dense reference.

use slope::backend::{gemm_nt, gemm_nt_with, spawned_thread_count, spmm_rowmajor,
                     spmm_rowmajor_with, spmm_tiled, spmm_tiled_with, ParallelPolicy,
                     PartitionStrategy, SparseBackend, SpmmAlgo};
use slope::serve::{BatchPolicy, LoraAdapter, ServeEngine, ServeLayer};
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::Rng;
use std::time::Duration;

const THREADS: [usize; 4] = [1, 2, 4, 7];

fn cols_policy(threads: usize) -> ParallelPolicy {
    ParallelPolicy { threads, min_rows_per_task: 1, partition: PartitionStrategy::Cols }
}

#[test]
fn col_partitioned_spmm_bit_identical_to_serial() {
    let mut rng = Rng::seed_from_u64(0x5e1);
    // Ragged on purpose: batches {1, 3, 23}, outs {7, 37, 53} — nothing
    // divides the stripe counts.
    for (b, d_out, d_in) in [(1usize, 37usize, 64usize), (3, 53, 32), (23, 7, 64), (1, 7, 8)] {
        let x = Matrix::randn(b, d_in, 1.0, &mut rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let serial = spmm_rowmajor(&x, &c);
        let serial_tiled = spmm_tiled(&x, &c, 8);
        for threads in THREADS {
            let p = cols_policy(threads);
            assert_eq!(spmm_rowmajor_with(&x, &c, &p), serial,
                       "spmm b={b} {d_out}x{d_in} t={threads}");
            assert_eq!(spmm_tiled_with(&x, &c, 8, &p), serial_tiled,
                       "tiled b={b} {d_out}x{d_in} t={threads}");
        }
    }
}

#[test]
fn col_partitioned_gemm_nt_bit_identical_to_serial() {
    let mut rng = Rng::seed_from_u64(0x5e2);
    for (m, k, n) in [(1usize, 32usize, 29usize), (2, 17, 61), (13, 64, 9)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let serial = gemm_nt(&a, &bt);
        for threads in THREADS {
            assert_eq!(gemm_nt_with(&a, &bt, &cols_policy(threads)), serial,
                       "gemm_nt {m}x{k}x{n} t={threads}");
        }
    }
}

#[test]
fn pool_reuses_workers_across_1000_regions() {
    let mut rng = Rng::seed_from_u64(0x5e3);
    let x = Matrix::randn(4, 32, 1.0, &mut rng);
    let w = Matrix::randn(24, 32, 1.0, &mut rng);
    let mask = random_row_mask(24, 32, NmScheme::TWO_FOUR, &mut rng);
    let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
    let serial = spmm_rowmajor(&x, &c);
    // Warm the global pool (first parallel region may spawn its workers),
    // then snapshot the process-wide spawn counter.
    let p_rows = ParallelPolicy { threads: 4, min_rows_per_task: 1,
                                  partition: PartitionStrategy::Rows };
    let p_cols = cols_policy(4);
    assert_eq!(spmm_rowmajor_with(&x, &c, &p_rows), serial);
    let spawned = spawned_thread_count();
    // ≥ 1000 parallel regions across both partition strategies: every one
    // must run on the already-parked workers.
    for i in 0..500 {
        let p = if i % 2 == 0 { p_rows } else { p_cols };
        assert_eq!(spmm_rowmajor_with(&x, &c, &p), serial, "region {i}");
        assert_eq!(gemm_nt_with(&x, &w, &p), gemm_nt(&x, &w), "gemm region {i}");
    }
    assert_eq!(spawned_thread_count(), spawned,
               "1000 regions must not spawn any new threads");
}

fn serve_layer(d_out: usize, d_in: usize, rank: usize, rng: &mut Rng) -> ServeLayer {
    let w = Matrix::randn(d_out, d_in, 1.0, rng);
    let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
    let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                  ParallelPolicy::with_threads(2));
    let lora = (rank > 0).then(|| LoraAdapter {
        up: Matrix::randn(d_out, rank, 0.3, rng),
        down: Matrix::randn(rank, d_in, 0.3, rng),
    });
    ServeLayer::new(be, lora).unwrap()
}

#[test]
fn serve_engine_coalesces_under_max_batch_and_max_wait() {
    let ms = Duration::from_millis(1);
    let mut rng = Rng::seed_from_u64(0x5e4);
    let mut eng = ServeEngine::new(
        vec![serve_layer(24, 16, 4, &mut rng), serve_layer(16, 24, 0, &mut rng)],
        BatchPolicy::new(4, 10 * ms),
    )
    .unwrap();

    // 5 requests at t = 0..4 ms: the first 4 coalesce into one full batch
    // the moment the 4th arrives; the 5th waits.
    for i in 0..5u64 {
        eng.submit(vec![0.1 * (i as f32 + 1.0); 16], i as u32 * ms).unwrap();
        if i < 3 {
            assert!(eng.poll(i as u32 * ms).unwrap().is_empty(),
                    "below max_batch and max_wait");
        }
    }
    let first = eng.poll(4 * ms).unwrap();
    assert_eq!(first.len(), 4, "max_batch dispatch");
    assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    assert_eq!(first[0].queued, 4 * ms, "oldest waited 4 ms");
    assert_eq!(eng.pending(), 1);

    // The straggler holds until its wait hits max_wait (submitted at
    // 4 ms ⇒ due at 14 ms), then dispatches as a partial batch.
    assert!(eng.poll(13 * ms).unwrap().is_empty(), "straggler below max_wait");
    let tail = eng.poll(14 * ms).unwrap();
    assert_eq!(tail.len(), 1, "max_wait flush");
    assert_eq!(tail[0].id, 4);
    assert!(tail[0].queued >= 10 * ms);

    let s = eng.stats().summary();
    assert_eq!(s.served, 5);
    assert_eq!(s.batches, 2);
    assert!((s.mean_batch_fill - 2.5).abs() < 1e-12);
}

#[test]
fn serve_engine_matches_dense_reference_across_fills() {
    let mut rng = Rng::seed_from_u64(0x5e5);
    let layers = vec![serve_layer(32, 16, 4, &mut rng), serve_layer(16, 32, 2, &mut rng)];
    // Dense reference on a 5-request batch.
    let x = Matrix::randn(5, 16, 1.0, &mut rng);
    let mut want = x.clone();
    for l in &layers {
        let mut y = gemm_nt(&want, &l.backend.dense_weight());
        if let Some(a) = &l.lora {
            let t = gemm_nt(&want, &a.down);
            let y2 = gemm_nt(&t, &a.up);
            for (o, v) in y.data.iter_mut().zip(&y2.data) {
                *o += v;
            }
        }
        want = y;
    }
    let mut eng =
        ServeEngine::new(layers, BatchPolicy::new(3, Duration::from_millis(1))).unwrap();
    for r in 0..5 {
        eng.submit(x.row(r).to_vec(), Duration::ZERO).unwrap();
    }
    // Fills 3 + 2: different staging shapes, same math.
    let mut got = eng.flush(Duration::ZERO).unwrap();
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), 5);
    for (row, resp) in got.iter().enumerate() {
        let g = Matrix::from_vec(1, want.cols, resp.output.clone());
        let wrow = Matrix::from_vec(1, want.cols, want.row(row).to_vec());
        assert!(g.max_abs_diff(&wrow) < 1e-3, "row {row}");
    }
}
