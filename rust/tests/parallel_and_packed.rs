//! Property suites for the parallel kernel engine and the Eq.-7 packed
//! metadata layout:
//!
//! * every parallel kernel is **bit-identical** to its serial form across
//!   thread counts {1, 2, 4, 7} at ragged (non-multiple) shapes — the
//!   engine contract `backend::pool` documents;
//! * `CompressedNm` packed-offset compress→decompress round-trips exactly
//!   for the 1:2, 2:4 and 2:8 schemes, and the packed plane is charged at
//!   the byte budget `memmodel::packed_metadata_bytes` predicts.

use slope::backend::{gemm, gemm_nt, gemm_nt_acc, gemm_nt_acc_into, gemm_nt_with, gemm_tn,
                     gemm_tn_with, gemm_with, lora_fused, lora_naive, sparse_dot_at,
                     sparse_dot_scalar, spmm_rowmajor, spmm_rowmajor_with, spmm_tiled,
                     spmm_tiled_with, ParallelPolicy, PartitionStrategy, SimdLevel,
                     SparseBackend, SpmmAlgo};
use slope::memmodel::packed_metadata_bytes;
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::proptest::cases;

const THREADS: [usize; 4] = [1, 2, 4, 7];
const PACK_SCHEMES: [(usize, usize); 3] = [(1, 2), (2, 4), (2, 8)];

/// Aggressive policy: forces real partitioning even at tiny row counts.
fn policy(threads: usize) -> ParallelPolicy {
    ParallelPolicy { threads, min_rows_per_task: 1, partition: PartitionStrategy::Auto }
}

#[test]
fn prop_parallel_gemm_family_bit_identical() {
    cases(20, 0x71, |g| {
        // Ragged shapes on purpose: nothing divides anything.
        let m = g.usize_in(1, 43);
        let k = g.usize_in(1, 67);
        let n = g.usize_in(1, 39);
        let a = Matrix::randn(m, k, 1.0, &mut g.rng);
        let b = Matrix::randn(k, n, 1.0, &mut g.rng);
        let bt = b.transpose(); // (n, k)
        let at = a.transpose(); // (k, m)
        let c0 = Matrix::randn(m, n, 1.0, &mut g.rng);

        let want = gemm(&a, &b);
        let want_nt = gemm_nt(&a, &bt);
        let want_tn = gemm_tn(&at, &b);
        let want_acc = gemm_nt_acc(&a, &bt, c0.clone());
        for t in THREADS {
            let p = policy(t);
            assert_eq!(gemm_with(&a, &b, &p), want, "gemm t={t} {m}x{k}x{n}");
            assert_eq!(gemm_nt_with(&a, &bt, &p), want_nt, "gemm_nt t={t}");
            assert_eq!(gemm_tn_with(&at, &b, &p), want_tn, "gemm_tn t={t}");
            let mut acc = c0.clone();
            gemm_nt_acc_into(&a, &bt, &mut acc, &p);
            assert_eq!(acc, want_acc, "gemm_nt_acc t={t}");
        }
    });
}

#[test]
fn prop_parallel_spmm_bit_identical() {
    cases(20, 0x72, |g| {
        let (n, m) = *g.pick(&[(1usize, 2usize), (2, 4), (2, 8), (4, 8)]);
        let s = NmScheme::new(n, m);
        let b = g.usize_in(1, 29); // ragged batch
        let d_in = g.dim_multiple_of(m, 9);
        let d_out = g.usize_in(1, 47); // ragged outs (exercises the quad tail)
        let x = Matrix::randn(b, d_in, 1.0, &mut g.rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut g.rng);
        let mask = random_row_mask(d_out, d_in, s, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, s);
        let want = spmm_rowmajor(&x, &c);
        let tile = g.usize_in(1, 33);
        let want_tiled = spmm_tiled(&x, &c, tile);
        // Tiling only reorders independent elements ⇒ exact agreement.
        assert_eq!(want, want_tiled, "{s} tile={tile}");
        for t in THREADS {
            for strategy in
                [PartitionStrategy::Auto, PartitionStrategy::Rows, PartitionStrategy::Cols]
            {
                let p = policy(t).with_partition(strategy);
                assert_eq!(spmm_rowmajor_with(&x, &c, &p), want, "{s} t={t} {strategy:?}");
                assert_eq!(spmm_tiled_with(&x, &c, tile, &p), want,
                           "{s} tiled t={t} {strategy:?}");
            }
        }
    });
}

#[test]
fn prop_byte_decode_matches_scalar_decode() {
    // The table-driven whole-byte 2:4 decode must agree bit-for-bit with
    // the scalar per-element packed decode on every row, including the
    // odd-group tail byte (cols ≡ 4 mod 8).  Pinned at SimdLevel::Scalar:
    // the AVX2 gather-dot is tolerance-pinned in tests/simd_parity.rs.
    cases(30, 0x76, |g| {
        let s = NmScheme::TWO_FOUR;
        let cols = g.dim_multiple_of(4, 16);
        let rows = g.usize_in(1, 17);
        let x = Matrix::randn(1, cols, 1.0, &mut g.rng);
        let w = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, s);
        let (kc, rmb) = (c.kcols(), c.row_meta_bytes());
        for o in 0..rows {
            let vals = &c.values[o * kc..(o + 1) * kc];
            let meta = &c.meta[o * rmb..(o + 1) * rmb];
            let fast =
                sparse_dot_at(SimdLevel::Scalar, x.row(0), vals, meta, s.n, s.m, s.offset_bits());
            let scalar = sparse_dot_scalar(x.row(0), vals, meta, s.n, s.m, s.offset_bits());
            assert_eq!(fast.to_bits(), scalar.to_bits(), "cols={cols} row={o}");
        }
    });
}

#[test]
fn prop_parallel_lora_paths_bit_identical() {
    cases(12, 0x73, |g| {
        let b = g.usize_in(1, 17);
        let d_in = g.dim_multiple_of(4, 8).max(8);
        let d_out = g.usize_in(1, 31);
        let r = g.usize_in(1, 9);
        let x = Matrix::randn(b, d_in, 1.0, &mut g.rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut g.rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let lo_up = Matrix::randn(d_out, r, 0.5, &mut g.rng);
        let lo_down = Matrix::randn(r, d_in, 0.5, &mut g.rng);
        let serial = policy(1);
        let want_naive = lora_naive(&x, &c, &lo_up, &lo_down, SpmmAlgo::RowMajor, &serial);
        let want_fused = lora_fused(&x, &c, &lo_up, &lo_down, SpmmAlgo::RowMajor, &serial);
        for t in THREADS {
            let p = policy(t);
            assert_eq!(lora_naive(&x, &c, &lo_up, &lo_down, SpmmAlgo::RowMajor, &p),
                       want_naive, "naive t={t}");
            assert_eq!(lora_fused(&x, &c, &lo_up, &lo_down, SpmmAlgo::RowMajor, &p),
                       want_fused, "fused t={t}");
        }
    });
}

#[test]
fn prop_backend_workspace_bit_identical_to_allocating_calls() {
    cases(10, 0x74, |g| {
        let b = g.usize_in(1, 12);
        let d_in = g.dim_multiple_of(4, 8).max(8);
        let d_out = g.dim_multiple_of(4, 6).max(8);
        let x = Matrix::randn(b, d_in, 1.0, &mut g.rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut g.rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut g.rng);
        let gy = Matrix::randn(b, d_out, 1.0, &mut g.rng);
        for t in [1usize, 4] {
            let mut be = SparseBackend::setup(&w, mask.clone(), NmScheme::TWO_FOUR,
                                              SpmmAlgo::RowMajor, policy(t));
            let want_y = be.forward(&x);
            let want_gx = be.grad_input(&gy);
            let want_gw = be.grad_weight(&gy, &x);
            // Run the workspace path twice: the second pass reuses warm
            // buffers and must still agree exactly.
            for pass in 0..2 {
                assert_eq!(*be.forward_ws(&x), want_y, "t={t} pass={pass}");
                assert_eq!(*be.grad_input_ws(&gy), want_gx, "t={t} pass={pass}");
                assert_eq!(*be.grad_weight_ws(&gy, &x), want_gw, "t={t} pass={pass}");
            }
        }
    });
}

#[test]
fn prop_packed_roundtrip_all_schemes() {
    cases(30, 0x75, |g| {
        let (n, m) = *g.pick(&PACK_SCHEMES);
        let s = NmScheme::new(n, m);
        let rows = g.usize_in(1, 24);
        let cols = g.dim_multiple_of(m, 10);
        let w = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let mask = random_row_mask(rows, cols, s, &mut g.rng);
        let c = CompressedNm::compress(&w, &mask, s);
        // Exact round-trip through the packed offsets.
        assert_eq!(c.decompress(), mask.apply(&w), "{s} {rows}x{cols}");
        // In-place update keeps the packed pattern intact.
        let w2 = Matrix::randn(rows, cols, 1.0, &mut g.rng);
        let mut c2 = c.clone();
        c2.update_from_dense(&w2);
        assert_eq!(c2.decompress(), mask.apply(&w2), "{s} update");
        assert_eq!(c2.meta, c.meta, "update must not touch metadata");
        // The plane size matches the memmodel's packed charge and beats
        // the old u16 plane by ≥ 4× for every scheme here (bit-level;
        // byte-level too once rows are wide enough to amortize the
        // byte-alignment pad).
        assert_eq!(c.meta_bytes(), packed_metadata_bytes(rows, cols, s), "{s}");
        let kept = rows * (cols / m * n);
        let packed_bits = kept * s.offset_bits() as usize;
        assert!(kept * 16 >= 4 * packed_bits.max(1), "{s}");
        if cols >= 64 {
            let u16_bytes = kept * 2;
            assert!(u16_bytes >= 4 * c.meta_bytes(),
                    "{s}: {u16_bytes} vs {}", c.meta_bytes());
        }
        // Offsets decode inside their group and strictly increase.
        for r in 0..rows {
            for grp in 0..cols / m {
                for i in 0..n {
                    let col = c.index(r, grp * n + i);
                    assert!(col >= grp * m && col < (grp + 1) * m);
                    if i > 0 {
                        assert!(c.index(r, grp * n + i - 1) < col);
                    }
                }
            }
        }
    });
}
