//! Integration tests over the Trainer — phase schedule, lazy-adapter
//! activation, checkpoint cadence, dense baseline — running on the
//! **host kernel executor** against fabricated artifacts, so the whole
//! training story executes in every `cargo test -q` with no
//! `make artifacts`.  (With real artifacts present the same Trainer
//! drives the PJRT route instead; `tests/integration_runtime.rs` covers
//! that side and still skips offline.)

use slope::backend::ParallelPolicy;
use slope::config::{Method, RunConfig};
use slope::coordinator::Trainer;
use slope::serve::{AotModel, DecodeEngine, DecodePolicy, Sampler};
use std::path::PathBuf;

/// Per-test artifact root (unique model name ⇒ unique fabricated dir and
/// session-cache key).
fn cfg(tag: &str, method: Method, steps: usize, lazy: f64) -> RunConfig {
    let root = std::env::temp_dir().join("slope_it_trainer");
    RunConfig {
        model: format!("it-{tag}"),
        method,
        steps,
        lazy_fraction: lazy,
        eval_every: steps.max(1),
        eval_batches: 2,
        seed: 3,
        artifacts: root,
        out_dir: std::env::temp_dir().join("slope_it_trainer_runs"),
        checkpoint_dir: None,
        resume: None,
        keep_checkpoints: 3,
        parallel: ParallelPolicy::serial(),
    }
}

fn clean(cfg: &RunConfig) -> PathBuf {
    let dir = cfg.artifacts.join(&cfg.model);
    // NOTE: sessions are cached per directory within a thread; each test
    // uses a distinct model name so a fresh fabrication is never shadowed
    // by another test's cached session.
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn slope_run_with_phase_flip_on_host_executor() {
    let cfg = cfg("phaseflip", Method::Slope, 12, 0.34);
    clean(&cfg);
    let mut t = Trainer::new(cfg).unwrap();
    t.init().unwrap();
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
    assert!(o.final_perplexity.is_finite());
    // Phase flip happened: sparse steps then lora steps.
    let phases: Vec<&str> = t.metrics.steps.iter().map(|s| s.phase).collect();
    assert!(phases.contains(&"sparse") && phases.contains(&"lora"), "{phases:?}");
    // The flip lands exactly at (1−λ)·T.
    let flip_at = t.cfg.sparse_steps();
    for rec in &t.metrics.steps {
        let want = if rec.step <= flip_at { "sparse" } else { "lora" };
        assert_eq!(rec.phase, want, "step {}", rec.step);
    }
    // Native training actually learns.
    assert!(
        o.final_loss < t.metrics.steps[0].loss,
        "loss did not go down: {} -> {}",
        t.metrics.steps[0].loss,
        o.final_loss
    );
    // Adapter-convergence records were captured during the lazy phase,
    // and the store carries live adapters.
    assert!(!t.metrics.adapters.is_empty());
    assert!(t.store.contains("lora.blocks.0.wqkv_up"));
    // Cloze probe ran through the host `forward` executable.
    assert!(o.cloze_accuracy.is_finite());
    // Metrics serialize and save.
    let path = t.metrics.save(&t.cfg.out_dir.clone()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let j = slope::util::Json::parse(&text).unwrap();
    assert_eq!(j.req("steps").unwrap().as_arr().unwrap().len(), 12);
}

#[test]
fn dense_baseline_uses_ones_masks_on_host_executor() {
    let cfg = cfg("dense", Method::Dense, 3, 0.0);
    clean(&cfg);
    let mut t = Trainer::new(cfg).unwrap();
    t.init().unwrap();
    let mask = t.store.read_f32("masks.blocks.1.wup_r").unwrap();
    assert!(mask.iter().all(|v| *v == 1.0), "dense run must see ones masks");
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
    // Dense weights are NOT support-constrained.
    let w = t.store.read_f32("params.blocks.1.wup").unwrap();
    let zeros = w.iter().filter(|v| **v == 0.0).count();
    assert!(zeros < w.len() / 10, "dense weights should stay dense ({zeros}/{})", w.len());
}

#[test]
fn sparse_weights_stay_on_support_through_host_steps() {
    let cfg = cfg("support", Method::Slope, 4, 0.0);
    clean(&cfg);
    let mut t = Trainer::new(cfg).unwrap();
    t.init().unwrap();
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
    // Algorithm-1 invariant: pruned slots are exactly zero after steps.
    let mask = t.store.read_f32("masks.blocks.1.wup_r").unwrap();
    let w = t.store.read_f32("params.blocks.1.wup").unwrap();
    for (mv, wv) in mask.iter().zip(&w) {
        if *mv == 0.0 {
            assert_eq!(*wv, 0.0, "pruned slot moved off zero");
        }
    }
    // 2:4 density on the support.
    let kept = mask.iter().filter(|v| **v != 0.0).count();
    assert_eq!(kept * 2, mask.len(), "mask must be exactly 2:4");
}

#[test]
fn checkpoint_cadence_feeds_serve_and_generate() {
    let mut cfg = cfg("ckpt", Method::Slope, 4, 0.0);
    cfg.eval_every = 2; // checkpoints at steps 0, 2, 4
    let ckpt = std::env::temp_dir().join("slope_it_trainer_ckpt");
    std::fs::remove_dir_all(&ckpt).ok();
    cfg.checkpoint_dir = Some(ckpt.clone());
    clean(&cfg);
    let mut t = Trainer::new(cfg).unwrap();
    t.init().unwrap();
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
    assert!(ckpt.join("model.slopeckpt").exists(), "serving checkpoint missing");
    assert!(ckpt.join("manifest.json").exists(), "manifest copy missing");

    // The acceptance pipeline: the checkpoint a host-executor training
    // run wrote serves autoregressive generation with zero artifacts.
    let model = AotModel::open(&ckpt, ParallelPolicy::with_threads(2)).unwrap();
    let vocab = model.manifest().config.vocab_size;
    let policy = DecodePolicy {
        max_batch: 2,
        max_new_tokens: 4,
        eos: None,
        sampler: Sampler::Greedy,
        seed: 0,
        queue_cap: None,
    };
    let mut eng = DecodeEngine::new(model, policy).unwrap();
    let start = std::time::Instant::now();
    eng.submit(vec![1, 2, 3], None, start.elapsed()).unwrap();
    eng.submit(vec![5], None, start.elapsed()).unwrap();
    let done = eng.run_to_completion(start).unwrap();
    assert_eq!(done.len(), 2);
    for g in &done {
        assert_eq!(g.tokens.len(), 4);
        for tok in &g.tokens {
            assert!(*tok >= 0 && (*tok as usize) < vocab);
        }
    }
    std::fs::remove_dir_all(&ckpt).ok();
}

#[test]
fn step_zero_checkpoint_survives_without_steps() {
    // `--steps 0` still leaves a servable checkpoint behind (the step-0
    // checkpoint point), straight from the host `init`.
    let mut cfg = cfg("ckpt0", Method::Slope, 0, 0.0);
    let ckpt = std::env::temp_dir().join("slope_it_trainer_ckpt0");
    std::fs::remove_dir_all(&ckpt).ok();
    cfg.checkpoint_dir = Some(ckpt.clone());
    clean(&cfg);
    let mut t = Trainer::new(cfg).unwrap();
    t.init().unwrap();
    let _ = t.train().unwrap();
    let model = AotModel::open(&ckpt, ParallelPolicy::serial()).unwrap();
    assert!(model.packed_restored() > 0, "packed planes must ship in the checkpoint");
    std::fs::remove_dir_all(&ckpt).ok();
}
