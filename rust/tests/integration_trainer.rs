//! Integration tests over the Trainer (phase schedule, baselines, metrics)
//! on the smallest artifact config.  Requires `make artifacts`.

use slope::config::{Fig9Variant, Method, RunConfig};
use slope::coordinator::Trainer;
use std::path::Path;

fn cfg(method: Method, steps: usize, lazy: f64) -> RunConfig {
    RunConfig {
        model: "gpt-nano-half-depth".into(),
        method,
        steps,
        lazy_fraction: lazy,
        eval_every: steps.max(1),
        eval_batches: 2,
        seed: 3,
        artifacts: "artifacts".into(),
        out_dir: std::env::temp_dir().join("slope_test_runs"),
        checkpoint_dir: None,
        parallel: slope::backend::ParallelPolicy::serial(),
    }
}

fn artifacts_present() -> bool {
    Path::new("artifacts/gpt-nano-half-depth/manifest.json").exists()
}

#[test]
fn slope_run_with_phase_flip() {
    if !artifacts_present() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return;
    }
    let mut t = Trainer::new(cfg(Method::Slope, 6, 0.34)).unwrap();
    t.init().unwrap();
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
    assert!(o.final_perplexity.is_finite());
    // Phase flip happened: last steps tagged "lora".
    let phases: Vec<&str> = t.metrics.steps.iter().map(|s| s.phase).collect();
    assert!(phases.contains(&"sparse") && phases.contains(&"lora"), "{phases:?}");
    // Loss goes down over the run.
    assert!(o.final_loss < t.metrics.steps[0].loss);
    // Adapter convergence records were captured during the lazy phase.
    assert!(!t.metrics.adapters.is_empty());
    // Metrics serialize and save.
    let path = t.metrics.save(&t.cfg.out_dir.clone()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let j = slope::util::Json::parse(&text).unwrap();
    assert_eq!(j.req("steps").unwrap().as_arr().unwrap().len(), 6);
}

#[test]
fn dense_baseline_uses_ones_masks() {
    if !artifacts_present() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return;
    }
    let mut t = Trainer::new(cfg(Method::Dense, 3, 0.0)).unwrap();
    t.init().unwrap();
    let mask = t.store.read_f32("masks.blocks.1.wup_r").unwrap();
    assert!(mask.iter().all(|v| *v == 1.0), "dense run must see ones masks");
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
    // Dense weights are NOT support-constrained.
    let w = t.store.read_f32("params.blocks.1.wup").unwrap();
    let zeros = w.iter().filter(|v| **v == 0.0).count();
    assert!(zeros < w.len() / 10, "dense weights should stay dense");
}

#[test]
fn srste_churn_metric_is_populated() {
    if !artifacts_present() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return;
    }
    // SR-STE executables are exported for gpt-nano (half-depth is core-only).
    let mut c = cfg(Method::Srste, 8, 0.0);
    c.model = "gpt-nano".into();
    let mut t = Trainer::new(c).unwrap();
    t.init().unwrap();
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
    assert!(!t.metrics.churn.is_empty(), "SR-STE must record mask churn");
    let last = t.metrics.churn.last().unwrap();
    // The final snapshot IS the converged mask: distance zero.
    assert!(last.frac_changed_vs_final.abs() < 1e-12);
}

#[test]
fn wanda_flow_installs_nm_masks_after_dense_training() {
    if !artifacts_present() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return;
    }
    let mut t = Trainer::new(cfg(Method::Wanda, 3, 0.0)).unwrap();
    t.init().unwrap();
    // This config has no wanda executable? half-depth exports core only —
    // use magnitude path guard: skip if absent.
    if !t.manifest.executables.contains_key("wanda_masks") {
        eprintln!("skipping: no wanda_masks exe for this config");
        return;
    }
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
}

#[test]
fn fig9_weight_static_matches_support_invariant() {
    if !artifacts_present() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return;
    }
    if !Path::new("artifacts/gpt-nano/train_step_fig9_weight_static.hlo.txt").exists() {
        eprintln!("skipping: fig9 set not exported");
        return;
    }
    let mut c = cfg(Method::Fig9(Fig9Variant::WeightStatic), 2, 0.0);
    c.model = "gpt-nano".into();
    let mut t = Trainer::new(c).unwrap();
    t.init().unwrap();
    let o = t.train().unwrap();
    assert!(o.final_loss.is_finite());
}

#[test]
fn coordinator_overhead_is_small() {
    if !artifacts_present() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return;
    }
    let mut t = Trainer::new(cfg(Method::Slope, 5, 0.0)).unwrap();
    t.init().unwrap();
    let o = t.train().unwrap();
    // L3 target (DESIGN.md §8): everything outside execute < 5% of step.
    assert!(
        o.coordinator_overhead < 0.05,
        "coordinator overhead {:.3} ≥ 5%",
        o.coordinator_overhead
    );
}
