//! Integration suites for autoregressive decode:
//!
//! * **KV parity** — prefill + incremental steps over the per-sequence
//!   `KvCache` reproduce the full-prefix recompute **bit-for-bit**, for
//!   ragged prompt lengths, decode batches {1, 4}, and kernel-engine
//!   threads {1, 4} (the acceptance pin for the decode refactor);
//! * **continuous batching** — sequences joining and leaving the running
//!   batch mid-stream produce exactly the token streams solo runs
//!   produce (greedy), through the `DecodeEngine` scheduler and the
//!   `AotModel` decode surface;
//! * **async admission** — N concurrent producers over `DecodeAdmission`
//!   get the same generations as inline submission, and the bounded
//!   queue sheds deterministically under the reject policy.

use slope::backend::ParallelPolicy;
use slope::coordinator::checkpoint;
use slope::runtime::{write_synthetic_artifact, HostModel, KvCache, Manifest, SynthSpec};
use slope::serve::{AotModel, DecodeAdmission, DecodeEngine, DecodeModel, DecodePolicy,
                   KernelDecodeModel, Overload, QueuePolicy, Sampler};
use slope::tensor::Matrix;
use slope::util::Rng;
use std::time::Duration;

fn synth_dir(tag: &str, seed: u64) -> (std::path::PathBuf, SynthSpec) {
    let dir = std::env::temp_dir().join(format!("slope_decode_{tag}"));
    let spec = SynthSpec { seed, ..SynthSpec::default() };
    write_synthetic_artifact(&dir, &spec).unwrap();
    (dir, spec)
}

fn host_model(dir: &std::path::Path, threads: usize) -> HostModel {
    let manifest = Manifest::load(dir).unwrap();
    let (store, packed) = checkpoint::load_model_checkpoint(dir).unwrap();
    HostModel::from_store(&manifest, &store, &packed, ParallelPolicy::with_threads(threads))
        .unwrap()
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Greedy-decode one prompt solo (batch 1) until the context fills;
/// returns the generated stream.  Each step is pinned bit-for-bit
/// against the full-prefix recompute of the same tokens.
fn solo_stream(hm: &mut HostModel, prompt: &[i32], pin_recompute: bool) -> Vec<i32> {
    let mut cache = hm.new_kv_cache();
    let mut y = Matrix::zeros(0, 0);
    hm.prefill_into(prompt, &mut cache, &mut y).unwrap();
    let mut toks = prompt.to_vec();
    let mut stream = Vec::new();
    loop {
        let next = argmax(y.row(0));
        stream.push(next);
        if cache.len() >= cache.capacity() {
            break;
        }
        toks.push(next);
        hm.decode_step_into(&[next], std::slice::from_mut(&mut cache), &mut y).unwrap();
        if pin_recompute {
            let mut y_full = Matrix::zeros(0, 0);
            hm.forward_prefix_logits_into(&toks, &mut y_full).unwrap();
            assert_eq!(y.data, y_full.data,
                       "incremental logits diverged at position {}", toks.len() - 1);
        }
    }
    stream
}

#[test]
fn kv_parity_ragged_lengths_batches_and_threads() {
    let (dir, spec) = synth_dir("parity", 41);
    let mut rng = Rng::seed_from_u64(0xDEC0);
    // Ragged prompt lengths, including the 1-token and (seq_len - 1) edges.
    let plens = [1usize, 3, 6, spec.seq_len - 1];
    let prompts: Vec<Vec<i32>> = plens
        .iter()
        .map(|&p| (0..p).map(|_| rng.below(spec.vocab) as i32).collect())
        .collect();
    for threads in [1usize, 4] {
        let mut hm = host_model(&dir, threads);
        // Solo streams, each step pinned against full recompute.
        let want: Vec<Vec<i32>> =
            prompts.iter().map(|p| solo_stream(&mut hm, p, true)).collect();

        // Batched decode over the ragged batch of 4: sequences leave the
        // batch individually as their contexts fill (the continuous-
        // batching shrink), and every stream must match its solo run
        // exactly.
        let mut caches: Vec<KvCache> = Vec::new();
        let mut last: Vec<i32> = Vec::new();
        let mut idxmap: Vec<usize> = Vec::new();
        let mut streams: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut y = Matrix::zeros(0, 0);
        for (i, p) in prompts.iter().enumerate() {
            let mut c = hm.new_kv_cache();
            hm.prefill_into(p, &mut c, &mut y).unwrap();
            let first = argmax(y.row(0));
            streams[i].push(first);
            if c.len() < c.capacity() {
                caches.push(c);
                last.push(first);
                idxmap.push(i);
            }
        }
        while !caches.is_empty() {
            hm.decode_step_into(&last, &mut caches, &mut y).unwrap();
            let k = caches.len();
            let mut keep = vec![true; k];
            for i in 0..k {
                let tok = argmax(y.row(i));
                streams[idxmap[i]].push(tok);
                last[i] = tok;
                if caches[i].len() >= caches[i].capacity() {
                    keep[i] = false;
                }
            }
            for i in (0..k).rev() {
                if !keep[i] {
                    caches.remove(i);
                    last.remove(i);
                    idxmap.remove(i);
                }
            }
        }
        for (i, s) in streams.iter().enumerate() {
            assert_eq!(s, &want[i],
                       "prompt {i} (len {}), {threads} thr: batched decode diverged",
                       plens[i]);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn continuous_batching_join_leave_matches_solo_runs() {
    let (dir, spec) = synth_dir("joinleave", 42);
    let mut rng = Rng::seed_from_u64(7);
    let specs: Vec<(Vec<i32>, usize)> = [2usize, 4, 3, 5, 2, 4]
        .iter()
        .zip([3usize, 1, 4, 2, 6, 3])
        .map(|(&plen, max_new)| {
            let p: Vec<i32> = (0..plen).map(|_| rng.below(spec.vocab) as i32).collect();
            (p, max_new)
        })
        .collect();

    // Solo ground truth: each request alone on a fresh engine.
    let mut want: Vec<Vec<i32>> = Vec::new();
    for (prompt, max_new) in &specs {
        let model = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
        let mut eng = DecodeEngine::new(
            model,
            DecodePolicy { max_batch: 4, max_new_tokens: 8, ..Default::default() },
        )
        .unwrap();
        eng.submit(prompt.clone(), Some(*max_new), Duration::ZERO).unwrap();
        let mut done = Vec::new();
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), *max_new);
        want.push(done[0].tokens.clone());
    }

    // Staggered arrivals over one shared engine (max_batch 3): sequences
    // join as slots free and leave at their own caps — the token streams
    // must be identical to the solo runs.
    let model = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
    let mut eng = DecodeEngine::new(
        model,
        DecodePolicy { max_batch: 3, max_new_tokens: 8, ..Default::default() },
    )
    .unwrap();
    let mut done = Vec::new();
    for chunk in specs.chunks(2) {
        for (prompt, max_new) in chunk {
            eng.submit(prompt.clone(), Some(*max_new), Duration::ZERO).unwrap();
        }
        done.extend(eng.step(Duration::ZERO).unwrap());
    }
    while eng.active() > 0 {
        done.extend(eng.step(Duration::ZERO).unwrap());
    }
    assert_eq!(done.len(), specs.len());
    done.sort_by_key(|g| g.id);
    for (i, g) in done.iter().enumerate() {
        assert_eq!(g.tokens, want[i],
                   "request {i}: continuous batching changed the stream");
        assert_eq!(g.prompt_len, specs[i].0.len());
    }
    assert_eq!(eng.model().live_seqs(), 0, "all sequences freed");
    let s = eng.stats().summary();
    assert_eq!(s.served, specs.len());
    assert_eq!(s.prefills, specs.len());
    let total: usize = specs.iter().map(|(_, n)| *n).sum();
    assert_eq!(s.tokens_out + s.prefills, total, "every token accounted for");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn temperature_sampling_is_reproducible_and_batch_invariant_rng() {
    let (dir, _spec) = synth_dir("temp", 43);
    let run = || -> Vec<Vec<i32>> {
        let model = AotModel::open(&dir, ParallelPolicy::serial()).unwrap();
        let mut eng = DecodeEngine::new(
            model,
            DecodePolicy {
                max_batch: 2,
                max_new_tokens: 4,
                sampler: Sampler::Temperature(0.8),
                seed: 99,
                ..Default::default()
            },
        )
        .unwrap();
        for p in [vec![1, 2], vec![3], vec![4, 5, 6]] {
            eng.submit(p, None, Duration::ZERO).unwrap();
        }
        let mut done = Vec::new();
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
        }
        done.sort_by_key(|g| g.id);
        done.into_iter().map(|g| g.tokens).collect()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed ⇒ same sampled streams, batching and all");
    assert!(a.iter().all(|t| t.len() == 4));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn decode_admission_concurrent_producers_match_inline() {
    let prompts: Vec<Vec<i32>> = (0..12u64)
        .map(|i| vec![(i % 7) as i32, ((i * 3) % 11) as i32 + 1])
        .collect();
    let make_engine = || -> slope::Result<DecodeEngine<KernelDecodeModel>> {
        let model = KernelDecodeModel::synthetic(48, 16, 32, 4, 10,
                                                 ParallelPolicy::with_threads(2), 0xFEED)?;
        DecodeEngine::new(
            model,
            DecodePolicy { max_batch: 3, max_new_tokens: 5, ..Default::default() },
        )
    };

    // Inline ground truth.
    let mut eng = make_engine().unwrap();
    for p in &prompts {
        eng.submit(p.clone(), None, Duration::ZERO).unwrap();
    }
    let mut done = Vec::new();
    while eng.active() > 0 {
        done.extend(eng.step(Duration::ZERO).unwrap());
    }
    done.sort_by_key(|g| g.id);
    let want: Vec<Vec<i32>> = done.into_iter().map(|g| g.tokens).collect();

    // Concurrent producers over the async front-end, arbitrary
    // interleaving — same streams.
    let adm = DecodeAdmission::spawn(make_engine, Duration::from_micros(100),
                                     QueuePolicy::unbounded());
    let producers = 3usize;
    let mut handles = Vec::new();
    for p in 0..producers {
        let client = adm.client();
        let mine: Vec<(u64, Vec<i32>)> = prompts
            .iter()
            .enumerate()
            .filter(|(i, _)| i % producers == p)
            .map(|(i, pr)| (i as u64, pr.clone()))
            .collect();
        handles.push(std::thread::spawn(move || -> Vec<(u64, Vec<i32>)> {
            for (tag, prompt) in &mine {
                client.submit(*tag, prompt.clone(), None).unwrap();
            }
            (0..mine.len())
                .map(|_| {
                    let (tag, gen) = client.recv().unwrap();
                    (tag, gen.tokens)
                })
                .collect()
        }));
    }
    let mut got: Vec<(u64, Vec<i32>)> = Vec::new();
    for h in handles {
        got.extend(h.join().expect("producer thread"));
    }
    assert_eq!(got.len(), prompts.len());
    got.sort_by_key(|(tag, _)| *tag);
    for (tag, tokens) in got {
        assert_eq!(tokens, want[tag as usize],
                   "request {tag}: concurrent admission changed the stream");
    }
    let stats = adm.finish().unwrap();
    assert_eq!(stats.served, prompts.len());
    assert!(stats.decode_p99_ms >= stats.decode_p50_ms);
    assert!(stats.p99_ms >= stats.p50_ms);
}

#[test]
fn decode_admission_bounded_reject_sheds_deterministically() {
    // Stall the dispatcher in build so the cap-2 channel fills.
    let build = || -> slope::Result<DecodeEngine<KernelDecodeModel>> {
        std::thread::sleep(Duration::from_millis(150));
        let model = KernelDecodeModel::synthetic(32, 16, 32, 0, 8,
                                                 ParallelPolicy::serial(), 5)?;
        DecodeEngine::new(
            model,
            DecodePolicy { max_batch: 2, max_new_tokens: 3, ..Default::default() },
        )
    };
    let adm = DecodeAdmission::spawn(build, Duration::from_micros(100),
                                     QueuePolicy::bounded(2, Overload::Reject));
    let client = adm.client();
    client.submit(0, vec![1, 2], None).unwrap();
    client.submit(1, vec![3], None).unwrap();
    let err = client.submit(2, vec![4], None).unwrap_err();
    assert!(err.to_string().contains("full"), "{err}");
    let mut tags = vec![client.recv().unwrap().0, client.recv().unwrap().0];
    tags.sort_unstable();
    assert_eq!(tags, vec![0, 1], "admitted requests complete after the stall");
    drop(client);
    let stats = adm.finish().unwrap();
    assert_eq!(stats.served, 2);
}
