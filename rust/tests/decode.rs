//! Integration suites for autoregressive decode:
//!
//! * **KV parity** — prefill + incremental steps over the per-sequence
//!   `KvCache` reproduce the full-prefix recompute **bit-for-bit**, for
//!   ragged prompt lengths, decode batches {1, 4}, and kernel-engine
//!   threads {1, 4} (the acceptance pin for the decode refactor);
//! * **continuous batching** — sequences joining and leaving the running
//!   batch mid-stream produce exactly the token streams solo runs
//!   produce (greedy), through the `DecodeEngine` scheduler and the
//!   `AotModel` decode surface;
//! * **async admission** — N concurrent producers over `DecodeAdmission`
//!   get the same generations as inline submission, and the bounded
//!   queue sheds deterministically under the reject policy;
//! * **paged KV pool** — f32 paging is bit-identical to full recompute
//!   for every block size (including 1- and 3-token blocks that split
//!   each sequence across many blocks), truncate returns whole blocks
//!   and replays bitwise, f16/int8 planes track the f32 logits within
//!   pinned tolerances (and are themselves run-to-run deterministic),
//!   interleaved prefill/free/truncate churn drains the pool completely
//!   without perturbing later generations, and pool exhaustion reaches
//!   the `DecodeEngine` as backpressure (requests complete serially)
//!   rather than a failed queue.

use slope::backend::ParallelPolicy;
use slope::coordinator::checkpoint;
use slope::runtime::{is_pool_exhausted, write_synthetic_artifact, HostModel, KvCache, KvDtype,
                     KvPoolConfig, Manifest, SynthSpec};
use slope::serve::{AotModel, DecodeAdmission, DecodeEngine, DecodeModel, DecodePolicy,
                   KernelDecodeModel, Overload, QueuePolicy, Sampler};
use slope::tensor::Matrix;
use slope::util::Rng;
use std::time::Duration;

fn synth_dir(tag: &str, seed: u64) -> (std::path::PathBuf, SynthSpec) {
    let dir = std::env::temp_dir().join(format!("slope_decode_{tag}"));
    let spec = SynthSpec { seed, ..SynthSpec::default() };
    write_synthetic_artifact(&dir, &spec).unwrap();
    (dir, spec)
}

fn host_model(dir: &std::path::Path, threads: usize) -> HostModel {
    let manifest = Manifest::load(dir).unwrap();
    let (store, packed) = checkpoint::load_model_checkpoint(dir).unwrap();
    HostModel::from_store(&manifest, &store, &packed, ParallelPolicy::with_threads(threads))
        .unwrap()
}

fn host_model_with_kv(dir: &std::path::Path, threads: usize, kv: KvPoolConfig) -> HostModel {
    let manifest = Manifest::load(dir).unwrap();
    let (store, packed) = checkpoint::load_model_checkpoint(dir).unwrap();
    HostModel::from_store_with_kv(&manifest, &store, &packed,
                                  ParallelPolicy::with_threads(threads), kv)
        .unwrap()
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Greedy-decode one prompt solo (batch 1) until the context fills;
/// returns the generated stream.  Each step is pinned bit-for-bit
/// against the full-prefix recompute of the same tokens.
fn solo_stream(hm: &mut HostModel, prompt: &[i32], pin_recompute: bool) -> Vec<i32> {
    let mut cache = hm.new_kv_cache();
    let mut y = Matrix::zeros(0, 0);
    hm.prefill_into(prompt, &mut cache, &mut y).unwrap();
    let mut toks = prompt.to_vec();
    let mut stream = Vec::new();
    loop {
        let next = argmax(y.row(0));
        stream.push(next);
        if cache.len() >= cache.capacity() {
            break;
        }
        toks.push(next);
        hm.decode_step_into(&[next], std::slice::from_mut(&mut cache), &mut y).unwrap();
        if pin_recompute {
            let mut y_full = Matrix::zeros(0, 0);
            hm.forward_prefix_logits_into(&toks, &mut y_full).unwrap();
            assert_eq!(y.data, y_full.data,
                       "incremental logits diverged at position {}", toks.len() - 1);
        }
    }
    stream
}

#[test]
fn kv_parity_ragged_lengths_batches_and_threads() {
    let (dir, spec) = synth_dir("parity", 41);
    let mut rng = Rng::seed_from_u64(0xDEC0);
    // Ragged prompt lengths, including the 1-token and (seq_len - 1) edges.
    let plens = [1usize, 3, 6, spec.seq_len - 1];
    let prompts: Vec<Vec<i32>> = plens
        .iter()
        .map(|&p| (0..p).map(|_| rng.below(spec.vocab) as i32).collect())
        .collect();
    for threads in [1usize, 4] {
        let mut hm = host_model(&dir, threads);
        // Solo streams, each step pinned against full recompute.
        let want: Vec<Vec<i32>> =
            prompts.iter().map(|p| solo_stream(&mut hm, p, true)).collect();

        // Batched decode over the ragged batch of 4: sequences leave the
        // batch individually as their contexts fill (the continuous-
        // batching shrink), and every stream must match its solo run
        // exactly.
        let mut caches: Vec<KvCache> = Vec::new();
        let mut last: Vec<i32> = Vec::new();
        let mut idxmap: Vec<usize> = Vec::new();
        let mut streams: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut y = Matrix::zeros(0, 0);
        for (i, p) in prompts.iter().enumerate() {
            let mut c = hm.new_kv_cache();
            hm.prefill_into(p, &mut c, &mut y).unwrap();
            let first = argmax(y.row(0));
            streams[i].push(first);
            if c.len() < c.capacity() {
                caches.push(c);
                last.push(first);
                idxmap.push(i);
            }
        }
        while !caches.is_empty() {
            hm.decode_step_into(&last, &mut caches, &mut y).unwrap();
            let k = caches.len();
            let mut keep = vec![true; k];
            for i in 0..k {
                let tok = argmax(y.row(i));
                streams[idxmap[i]].push(tok);
                last[i] = tok;
                if caches[i].len() >= caches[i].capacity() {
                    keep[i] = false;
                }
            }
            for i in (0..k).rev() {
                if !keep[i] {
                    caches.remove(i);
                    last.remove(i);
                    idxmap.remove(i);
                }
            }
        }
        for (i, s) in streams.iter().enumerate() {
            assert_eq!(s, &want[i],
                       "prompt {i} (len {}), {threads} thr: batched decode diverged",
                       plens[i]);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn continuous_batching_join_leave_matches_solo_runs() {
    let (dir, spec) = synth_dir("joinleave", 42);
    let mut rng = Rng::seed_from_u64(7);
    let specs: Vec<(Vec<i32>, usize)> = [2usize, 4, 3, 5, 2, 4]
        .iter()
        .zip([3usize, 1, 4, 2, 6, 3])
        .map(|(&plen, max_new)| {
            let p: Vec<i32> = (0..plen).map(|_| rng.below(spec.vocab) as i32).collect();
            (p, max_new)
        })
        .collect();

    // Solo ground truth: each request alone on a fresh engine.
    let mut want: Vec<Vec<i32>> = Vec::new();
    for (prompt, max_new) in &specs {
        let model = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
        let mut eng = DecodeEngine::new(
            model,
            DecodePolicy { max_batch: 4, max_new_tokens: 8, ..Default::default() },
        )
        .unwrap();
        eng.submit(prompt.clone(), Some(*max_new), Duration::ZERO).unwrap();
        let mut done = Vec::new();
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), *max_new);
        want.push(done[0].tokens.clone());
    }

    // Staggered arrivals over one shared engine (max_batch 3): sequences
    // join as slots free and leave at their own caps — the token streams
    // must be identical to the solo runs.
    let model = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
    let mut eng = DecodeEngine::new(
        model,
        DecodePolicy { max_batch: 3, max_new_tokens: 8, ..Default::default() },
    )
    .unwrap();
    let mut done = Vec::new();
    for chunk in specs.chunks(2) {
        for (prompt, max_new) in chunk {
            eng.submit(prompt.clone(), Some(*max_new), Duration::ZERO).unwrap();
        }
        done.extend(eng.step(Duration::ZERO).unwrap());
    }
    while eng.active() > 0 {
        done.extend(eng.step(Duration::ZERO).unwrap());
    }
    assert_eq!(done.len(), specs.len());
    done.sort_by_key(|g| g.id);
    for (i, g) in done.iter().enumerate() {
        assert_eq!(g.tokens, want[i],
                   "request {i}: continuous batching changed the stream");
        assert_eq!(g.prompt_len, specs[i].0.len());
    }
    assert_eq!(eng.model().live_seqs(), 0, "all sequences freed");
    let s = eng.stats().summary();
    assert_eq!(s.served, specs.len());
    assert_eq!(s.prefills, specs.len());
    let total: usize = specs.iter().map(|(_, n)| *n).sum();
    assert_eq!(s.tokens_out + s.prefills, total, "every token accounted for");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn temperature_sampling_is_reproducible_and_batch_invariant_rng() {
    let (dir, _spec) = synth_dir("temp", 43);
    let run = || -> Vec<Vec<i32>> {
        let model = AotModel::open(&dir, ParallelPolicy::serial()).unwrap();
        let mut eng = DecodeEngine::new(
            model,
            DecodePolicy {
                max_batch: 2,
                max_new_tokens: 4,
                sampler: Sampler::Temperature(0.8),
                seed: 99,
                ..Default::default()
            },
        )
        .unwrap();
        for p in [vec![1, 2], vec![3], vec![4, 5, 6]] {
            eng.submit(p, None, Duration::ZERO).unwrap();
        }
        let mut done = Vec::new();
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
        }
        done.sort_by_key(|g| g.id);
        done.into_iter().map(|g| g.tokens).collect()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed ⇒ same sampled streams, batching and all");
    assert!(a.iter().all(|t| t.len() == 4));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn decode_admission_concurrent_producers_match_inline() {
    let prompts: Vec<Vec<i32>> = (0..12u64)
        .map(|i| vec![(i % 7) as i32, ((i * 3) % 11) as i32 + 1])
        .collect();
    let make_engine = || -> slope::Result<DecodeEngine<KernelDecodeModel>> {
        let model = KernelDecodeModel::synthetic(48, 16, 32, 4, 10,
                                                 ParallelPolicy::with_threads(2), 0xFEED)?;
        DecodeEngine::new(
            model,
            DecodePolicy { max_batch: 3, max_new_tokens: 5, ..Default::default() },
        )
    };

    // Inline ground truth.
    let mut eng = make_engine().unwrap();
    for p in &prompts {
        eng.submit(p.clone(), None, Duration::ZERO).unwrap();
    }
    let mut done = Vec::new();
    while eng.active() > 0 {
        done.extend(eng.step(Duration::ZERO).unwrap());
    }
    done.sort_by_key(|g| g.id);
    let want: Vec<Vec<i32>> = done.into_iter().map(|g| g.tokens).collect();

    // Concurrent producers over the async front-end, arbitrary
    // interleaving — same streams.
    let adm = DecodeAdmission::spawn(make_engine, Duration::from_micros(100),
                                     QueuePolicy::unbounded());
    let producers = 3usize;
    let mut handles = Vec::new();
    for p in 0..producers {
        let client = adm.client();
        let mine: Vec<(u64, Vec<i32>)> = prompts
            .iter()
            .enumerate()
            .filter(|(i, _)| i % producers == p)
            .map(|(i, pr)| (i as u64, pr.clone()))
            .collect();
        handles.push(std::thread::spawn(move || -> Vec<(u64, Vec<i32>)> {
            for (tag, prompt) in &mine {
                client.submit(*tag, prompt.clone(), None).unwrap();
            }
            (0..mine.len())
                .map(|_| {
                    let (tag, gen) = client.recv().unwrap();
                    (tag, gen.tokens)
                })
                .collect()
        }));
    }
    let mut got: Vec<(u64, Vec<i32>)> = Vec::new();
    for h in handles {
        got.extend(h.join().expect("producer thread"));
    }
    assert_eq!(got.len(), prompts.len());
    got.sort_by_key(|(tag, _)| *tag);
    for (tag, tokens) in got {
        assert_eq!(tokens, want[tag as usize],
                   "request {tag}: concurrent admission changed the stream");
    }
    let stats = adm.finish().unwrap();
    assert_eq!(stats.served, prompts.len());
    assert!(stats.decode_p99_ms >= stats.decode_p50_ms);
    assert!(stats.p99_ms >= stats.p50_ms);
}

#[test]
fn decode_admission_bounded_reject_sheds_deterministically() {
    // Stall the dispatcher in build so the cap-2 channel fills.
    let build = || -> slope::Result<DecodeEngine<KernelDecodeModel>> {
        std::thread::sleep(Duration::from_millis(150));
        let model = KernelDecodeModel::synthetic(32, 16, 32, 0, 8,
                                                 ParallelPolicy::serial(), 5)?;
        DecodeEngine::new(
            model,
            DecodePolicy { max_batch: 2, max_new_tokens: 3, ..Default::default() },
        )
    };
    let adm = DecodeAdmission::spawn(build, Duration::from_micros(100),
                                     QueuePolicy::bounded(2, Overload::Reject));
    let client = adm.client();
    client.submit(0, vec![1, 2], None).unwrap();
    client.submit(1, vec![3], None).unwrap();
    let err = client.submit(2, vec![4], None).unwrap_err();
    assert!(err.to_string().contains("full"), "{err}");
    let mut tags = vec![client.recv().unwrap().0, client.recv().unwrap().0];
    tags.sort_unstable();
    assert_eq!(tags, vec![0, 1], "admitted requests complete after the stall");
    drop(client);
    let stats = adm.finish().unwrap();
    assert_eq!(stats.served, 2);
}

#[test]
fn paged_f32_is_bitwise_identical_across_block_sizes() {
    let (dir, spec) = synth_dir("blocks", 44);
    let mut rng = Rng::seed_from_u64(0xB10C);
    let prompts: Vec<Vec<i32>> = [1usize, 5, spec.seq_len - 2]
        .iter()
        .map(|&p| (0..p).map(|_| rng.below(spec.vocab) as i32).collect())
        .collect();
    // Reference streams on the default pool (16-token blocks: every
    // sequence fits one block), each step already pinned bit-for-bit
    // against full recompute inside `solo_stream`.
    let mut hm_ref = host_model(&dir, 2);
    let want: Vec<Vec<i32>> =
        prompts.iter().map(|p| solo_stream(&mut hm_ref, p, true)).collect();
    // Pathological block sizes split the same sequences across many
    // blocks (1-token blocks: one block per position).  The paged reads
    // must still be bit-identical — and the recompute pin re-asserts the
    // full logits at every step, not just the argmax stream.
    for bt in [1usize, 3, 5] {
        let kv = KvPoolConfig { block_tokens: bt, ..KvPoolConfig::default() };
        let mut hm = host_model_with_kv(&dir, 2, kv);
        for (i, (p, w)) in prompts.iter().zip(&want).enumerate() {
            assert_eq!(&solo_stream(&mut hm, p, true), w,
                       "prompt {i}: {bt}-token blocks changed the stream");
        }
        assert_eq!(hm.kv_pool().stats().blocks_in_use, 0,
                   "{bt}-token blocks: dropped caches must drain the pool");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncate_frees_whole_blocks_and_replays_bitwise() {
    let (dir, spec) = synth_dir("trunc", 45);
    let kv = KvPoolConfig { block_tokens: 3, ..KvPoolConfig::default() };
    let mut hm = host_model_with_kv(&dir, 1, kv);
    let bb = hm.kv_pool().block_bytes();
    let mut cache = hm.new_kv_cache();
    let mut y = Matrix::zeros(0, 0);
    let prompt: Vec<i32> = (0..7).map(|i| (i * 5) % spec.vocab as i32).collect();
    hm.prefill_into(&prompt, &mut cache, &mut y).unwrap();
    assert_eq!(cache.bytes(), 3 * bb, "7 tokens over 3-token blocks = 3 blocks");
    let steps = [4i32, 9];
    let mut snaps: Vec<Vec<f32>> = Vec::new();
    for t in steps {
        hm.decode_step_into(&[t], std::slice::from_mut(&mut cache), &mut y).unwrap();
        snaps.push(y.data.clone());
    }
    assert_eq!(cache.len(), 9);
    assert_eq!(cache.bytes(), 3 * bb, "9 tokens still fit 3 blocks exactly");
    // Roll back over the decoded tokens and replay them: same logits,
    // bit for bit, through recycled block storage.
    cache.truncate(7);
    assert_eq!(cache.bytes(), 3 * bb, "len 7 still needs 3 blocks");
    for (t, snap) in steps.iter().zip(&snaps) {
        hm.decode_step_into(&[*t], std::slice::from_mut(&mut cache), &mut y).unwrap();
        assert_eq!(&y.data, snap, "replay after truncate diverged");
    }
    // Truncating past a block boundary returns whole blocks — and the
    // byte accounting shrinks with them (it used to stay at high-water).
    cache.truncate(6);
    assert_eq!(cache.bytes(), 2 * bb, "a cleared block boundary frees the block");
    cache.truncate(2);
    assert_eq!(cache.bytes(), bb);
    cache.reset();
    assert_eq!(cache.bytes(), 0);
    assert_eq!(hm.kv_pool().stats().blocks_in_use, 0);
    assert!(hm.kv_pool().stats().blocks_recycled > 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn f16_and_int8_planes_track_f32_within_pinned_tolerance() {
    let (dir, spec) = synth_dir("dtype", 46);
    let mut rng = Rng::seed_from_u64(0xD7);
    let prompt: Vec<i32> = (0..6).map(|_| rng.below(spec.vocab) as i32).collect();
    // Walk a FIXED token schedule (not greedy) so every dtype sees
    // byte-identical inputs and the logit gap is purely KV storage.
    let run = |dtype: KvDtype| -> Vec<Vec<f32>> {
        let kv = KvPoolConfig { dtype, ..KvPoolConfig::default() };
        let mut hm = host_model_with_kv(&dir, 2, kv);
        let mut cache = hm.new_kv_cache();
        let mut y = Matrix::zeros(0, 0);
        hm.prefill_into(&prompt, &mut cache, &mut y).unwrap();
        let mut out = vec![y.data.clone()];
        let mut t = 1i32;
        while cache.len() < cache.capacity() {
            hm.decode_step_into(&[t], std::slice::from_mut(&mut cache), &mut y).unwrap();
            out.push(y.data.clone());
            t = (t + 7) % spec.vocab as i32;
        }
        out
    };
    let reference = run(KvDtype::F32);
    let scale = reference
        .iter()
        .flatten()
        .fold(0f32, |m, v| m.max(v.abs()))
        .max(1e-6);
    for (dtype, tol) in [(KvDtype::F16, 1e-2f32), (KvDtype::Int8, 0.15)] {
        let got = run(dtype);
        assert_eq!(got.len(), reference.len());
        let mut worst = 0f32;
        for (a, b) in got.iter().flatten().zip(reference.iter().flatten()) {
            worst = worst.max((a - b).abs());
        }
        assert!(worst <= tol * scale,
                "{dtype:?}: worst |Δlogit| {worst} exceeds {tol} × max|logit| {scale}");
        // Quantization must be deterministic: a fresh model on the same
        // schedule reproduces the quantized logits bit for bit.
        assert_eq!(run(dtype), got, "{dtype:?} logits must be run-to-run identical");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prefix_cache_hits_are_bit_identical_across_block_sizes_and_threads() {
    let (dir, spec) = synth_dir("prefix_id", 49);
    let mut rng = Rng::seed_from_u64(0xCAFE);
    let base: Vec<i32> = (0..8).map(|_| rng.below(spec.vocab) as i32).collect();
    // A prompt family mixing hits and misses: an exact repeat, a
    // partial-prefix divergence, a disjoint miss, and a prompt shorter
    // than the cached chain.
    let prompts: Vec<Vec<i32>> = vec![
        base.clone(),
        base.clone(),
        { let mut p = base[..5].to_vec(); p.extend_from_slice(&[1, 2, 3]); p },
        (0..6).map(|_| rng.below(spec.vocab) as i32).collect(),
        base[..3].to_vec(),
    ];
    for bt in [1usize, 3, 5, 16] {
        for threads in [1usize, 4] {
            let mut hm_off = host_model_with_kv(
                &dir, threads,
                KvPoolConfig { block_tokens: bt, ..KvPoolConfig::default() });
            let mut hm_on = host_model_with_kv(
                &dir, threads,
                KvPoolConfig { block_tokens: bt, prefix_cache: Some(64),
                               ..KvPoolConfig::default() });
            let (mut y_off, mut y_on) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
            let mut c_off: Vec<KvCache> = Vec::new();
            let mut c_on: Vec<KvCache> = Vec::new();
            let mut last: Vec<i32> = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                let mut c = hm_off.new_kv_cache();
                hm_off.prefill_into(p, &mut c, &mut y_off).unwrap();
                c_off.push(c);
                let mut c = hm_on.new_kv_cache();
                let saved = hm_on.prefill_into_saved(p, &mut c, &mut y_on).unwrap();
                c_on.push(c);
                assert_eq!(y_on.data, y_off.data,
                           "bt {bt}, {threads} thr, prompt {i} (saved {saved}): \
                            cache-hit prefill logits diverged");
                last.push(argmax(y_off.row(0)));
            }
            // Mixed hit/miss batch, forced per-lane tokens so the two
            // identical prompts diverge immediately: copy-on-write must
            // keep every lane bitwise equal to the cache-off run.
            for step in 0..4i32 {
                let toks: Vec<i32> = (0..last.len() as i32)
                    .map(|i| (i * 7 + step * 3 + 1) % spec.vocab as i32)
                    .collect();
                hm_off.decode_step_into(&toks, &mut c_off, &mut y_off).unwrap();
                hm_on.decode_step_into(&toks, &mut c_on, &mut y_on).unwrap();
                assert_eq!(y_on.data, y_off.data,
                           "bt {bt}, {threads} thr, step {step}: shared-prefix \
                            decode diverged from the cache-off run");
            }
            let st = hm_on.kv_pool().prefix_stats().unwrap();
            assert_eq!(st.lookups, prompts.len() as u64,
                       "every multi-token prompt consults the cache");
            // The exact repeat shares whole blocks whenever one fits in
            // its matchable 7-token prefix (every bt here but 16).
            if bt < 8 {
                assert!(st.hits >= 1 && st.tokens_saved > 0,
                        "bt {bt}: repeat prompt must hit: {st:?}");
            }
            drop(c_on);
            drop(c_off);
            hm_on.kv_pool().clear_prefix_cache();
            assert_eq!(hm_on.kv_pool().stats().blocks_in_use, 0,
                       "bt {bt}: refcounts drained after drop + clear");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pool_stress_interleaved_churn_recycles_every_block_and_stays_bitwise() {
    let (dir, spec) = synth_dir("stress", 47);
    let mut rng = Rng::seed_from_u64(0xACE);
    let prompts: Vec<Vec<i32>> = (0..6usize)
        .map(|i| (0..(1 + i % 4)).map(|_| rng.below(spec.vocab) as i32).collect())
        .collect();

    // Greedy generation over the AotModel decode surface (SeqSlab slots
    // + pool blocks), `steps` tokens per prompt.
    fn greedy(model: &mut AotModel, prompt: &[i32], steps: usize) -> Vec<i32> {
        let mut y = Matrix::zeros(0, 0);
        let seq = model.prefill(prompt, &mut y).unwrap();
        let mut out = vec![argmax(y.row(0))];
        for _ in 1..steps {
            let t = *out.last().unwrap();
            model.decode_step(&[seq], &[t], &mut y).unwrap();
            out.push(argmax(y.row(0)));
        }
        model.free_seq(seq).unwrap();
        out
    }

    // Reference streams from a model that has seen no churn.
    let mut fresh = AotModel::open_with_kv(&dir, ParallelPolicy::with_threads(2),
                                           KvPoolConfig::default())
        .unwrap();
    let want: Vec<Vec<i32>> = prompts.iter().map(|p| greedy(&mut fresh, p, 4)).collect();

    // Churn the slab and the pool: waves of prefill / partial decode /
    // scrambled-order free, so slots and blocks are recycled across
    // sequences with different lengths.
    let mut model = AotModel::open_with_kv(&dir, ParallelPolicy::with_threads(2),
                                           KvPoolConfig::default())
        .unwrap();
    for wave in 0..3 {
        let mut y = Matrix::zeros(0, 0);
        let mut live = Vec::new();
        for p in &prompts {
            live.push(model.prefill(p, &mut y).unwrap());
        }
        // A couple of coalesced steps over the whole wave.
        let toks: Vec<i32> = (0..live.len() as i32).collect();
        model.decode_step(&live, &toks, &mut y).unwrap();
        model.decode_step(&live, &toks, &mut y).unwrap();
        // Free odd slots first, then evens — freed blocks interleave
        // back into the free-list out of allocation order.
        for (i, seq) in live.iter().enumerate() {
            if i % 2 == 1 {
                model.free_seq(*seq).unwrap();
            }
        }
        for (i, seq) in live.iter().enumerate() {
            if i % 2 == 0 {
                model.free_seq(*seq).unwrap();
            }
        }
        assert_eq!(model.live_seqs(), 0, "wave {wave}: slab drained");
        let ps = model.kv_pool_stats().unwrap();
        assert_eq!(ps.blocks_in_use, 0, "wave {wave}: every block back on the free-list");
    }
    let ps = model.kv_pool_stats().unwrap();
    assert!(ps.blocks_recycled > 0, "churn must exercise block recycling");
    assert!(ps.peak_blocks >= prompts.len(), "all waves held blocks concurrently");

    // HostModel-level churn with truncate in the mix, on tiny blocks so
    // truncation actually crosses block boundaries.
    let kv = KvPoolConfig { block_tokens: 2, ..KvPoolConfig::default() };
    let mut hm = host_model_with_kv(&dir, 2, kv);
    let mut y = Matrix::zeros(0, 0);
    let mut caches: Vec<KvCache> = prompts
        .iter()
        .map(|p| {
            let mut c = hm.new_kv_cache();
            hm.prefill_into(p, &mut c, &mut y).unwrap();
            c
        })
        .collect();
    let toks: Vec<i32> = (0..caches.len() as i32).map(|t| t % spec.vocab as i32).collect();
    hm.decode_step_into(&toks, &mut caches, &mut y).unwrap();
    hm.decode_step_into(&toks, &mut caches, &mut y).unwrap();
    for (i, c) in caches.iter_mut().enumerate() {
        c.truncate(c.len() - 1 - i % 2); // ragged rollback across block edges
    }
    hm.decode_step_into(&toks, &mut caches, &mut y).unwrap();
    caches.truncate(3); // drop half the caches entirely (Drop frees blocks)
    hm.decode_step_into(&toks[..3], &mut caches, &mut y).unwrap();
    drop(caches);
    let ps = hm.kv_pool().stats();
    assert_eq!(ps.blocks_in_use, 0, "post-churn: pool fully drained");
    assert!(ps.blocks_recycled > 0);

    // Prefix-cache churn: waves of repeated prompts over tiny blocks,
    // every wave's prefills and decode pinned bitwise against a
    // cache-off twin, and every shared refcount drained once the
    // sequences drop and the cache is cleared.
    let mut hm_pc = host_model_with_kv(
        &dir, 2,
        KvPoolConfig { block_tokens: 2, prefix_cache: Some(16),
                       ..KvPoolConfig::default() });
    let mut hm_ref = host_model_with_kv(
        &dir, 2, KvPoolConfig { block_tokens: 2, ..KvPoolConfig::default() });
    for wave in 0..3 {
        let (mut y, mut yr) = (Matrix::zeros(0, 0), Matrix::zeros(0, 0));
        let mut caches: Vec<KvCache> = Vec::new();
        let mut refs: Vec<KvCache> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let mut c = hm_pc.new_kv_cache();
            hm_pc.prefill_into(p, &mut c, &mut y).unwrap();
            let mut cr = hm_ref.new_kv_cache();
            hm_ref.prefill_into(p, &mut cr, &mut yr).unwrap();
            assert_eq!(y.data, yr.data, "wave {wave}, prompt {i}: cached prefill diverged");
            caches.push(c);
            refs.push(cr);
        }
        let toks: Vec<i32> = (0..caches.len() as i32).collect();
        hm_pc.decode_step_into(&toks, &mut caches, &mut y).unwrap();
        hm_ref.decode_step_into(&toks, &mut refs, &mut yr).unwrap();
        assert_eq!(y.data, yr.data, "wave {wave}: shared-prefix decode diverged");
    }
    let st = hm_pc.kv_pool().prefix_stats().unwrap();
    assert!(st.hits > 0, "repeated waves must hit the prefix cache: {st:?}");
    hm_pc.kv_pool().clear_prefix_cache();
    assert_eq!(hm_pc.kv_pool().stats().blocks_in_use, 0,
               "post-churn: every shared refcount drained");

    // Post-churn generations through recycled slots and blocks are
    // byte-identical to the churn-free reference.
    for (i, (p, w)) in prompts.iter().zip(&want).enumerate() {
        assert_eq!(&greedy(&mut model, p, 4), w,
                   "prompt {i}: churn perturbed a later generation");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pool_exhaustion_backpressures_the_decode_engine() {
    let (dir, _spec) = synth_dir("exhaust", 48);
    // Capacity (seq_len 12) fits one default 16-token block, so a
    // 1-block pool admits exactly one sequence at a time.
    let policy = || DecodePolicy { max_batch: 2, max_new_tokens: 3, ..Default::default() };
    let solo = |prompt: Vec<i32>| -> Vec<i32> {
        let m = AotModel::open_with_kv(&dir, ParallelPolicy::with_threads(2),
                                       KvPoolConfig::default())
            .unwrap();
        let mut eng = DecodeEngine::new(m, policy()).unwrap();
        eng.submit(prompt, Some(3), Duration::ZERO).unwrap();
        let mut done = Vec::new();
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
        }
        done.pop().unwrap().tokens
    };
    let want_a = solo(vec![1, 2]);
    let want_b = solo(vec![3, 4, 5]);

    let kv = KvPoolConfig { max_blocks: Some(1), ..KvPoolConfig::default() };
    let model = AotModel::open_with_kv(&dir, ParallelPolicy::with_threads(2), kv).unwrap();
    let mut eng = DecodeEngine::new(model, policy()).unwrap();
    eng.submit(vec![1, 2], Some(3), Duration::ZERO).unwrap();
    eng.submit(vec![3, 4, 5], Some(3), Duration::ZERO).unwrap();
    let mut done = Vec::new();
    let mut rounds = 0usize;
    while eng.active() > 0 {
        done.extend(eng.step(Duration::ZERO).unwrap());
        rounds += 1;
        assert!(rounds < 64, "exhaustion backpressure deadlocked");
    }
    assert_eq!(done.len(), 2, "both requests complete, serialized by the pool");
    done.sort_by_key(|g| g.id);
    assert_eq!(done[0].tokens, want_a);
    assert_eq!(done[1].tokens, want_b);
    let ps = eng.model().kv_pool_stats().unwrap();
    assert_eq!(ps.blocks_in_use, 0);
    assert!(ps.alloc_failures > 0, "the block cap must actually have bound");

    // With nothing running that could ever free a block, the pool error
    // surfaces instead of spinning forever.
    let starved = AotModel::open_with_kv(
        &dir,
        ParallelPolicy::serial(),
        KvPoolConfig { max_blocks: Some(0), ..KvPoolConfig::default() },
    )
    .unwrap();
    let mut eng = DecodeEngine::new(starved, policy()).unwrap();
    eng.submit(vec![1], Some(2), Duration::ZERO).unwrap();
    let err = eng.step(Duration::ZERO).unwrap_err();
    assert!(is_pool_exhausted(&err), "unexpected error: {err}");
    std::fs::remove_dir_all(&dir).ok();
}
