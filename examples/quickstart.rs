//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! Loads the `gpt-nano` AOT artifacts, initializes SLoPe state (random
//! static 2:4 masks, Eq. 4–6 double-pruned backward), runs a handful of
//! sparse train steps, evaluates, and shows the N:M/compression substrate.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use slope::backend::{gemm_nt, ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::config::{Method, RunConfig};
use slope::coordinator::Trainer;
use slope::sparsity::{random_row_mask, NmScheme};
use slope::tensor::Matrix;
use slope::util::Rng;

fn main() -> slope::Result<()> {
    // ---- 1. The sparsity substrate (no artifacts needed) -----------------
    let mut rng = Rng::seed_from_u64(0);
    let w = Matrix::randn(64, 128, 0.5, &mut rng);
    let mask = random_row_mask(64, 128, NmScheme::TWO_FOUR, &mut rng);
    let policy = ParallelPolicy::auto();
    let mut be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor, policy);
    let x = Matrix::randn(8, 128, 1.0, &mut rng);
    let y = be.forward(&x);
    let dense = gemm_nt(&x, &be.mask_r.apply(&w));
    println!(
        "sparse backend: 2:4 fwd max|Δ| vs dense = {:.2e}; W density {:.3}, W^RC density {:.3}",
        y.max_abs_diff(&dense),
        be.mask_r.density(),
        be.mask_rc.density()
    );
    println!(
        "kernel engine: {} thread(s); packed Eq.-7 metadata: {} B (u16 layout would be {} B)",
        be.policy.effective_threads(),
        be.w.meta_bytes(),
        be.w.rows * be.w.kcols() * 2
    );
    // Allocation-free serving call: same result, reused workspace buffer.
    let y_ws = be.forward_ws(&x);
    assert_eq!(*y_ws, y);

    // ---- 2. The AOT training pipeline ------------------------------------
    let cfg = RunConfig {
        model: "gpt-nano".into(),
        method: Method::Slope,
        steps: 10,
        lazy_fraction: 0.2, // adapters appear for the last 2 steps
        eval_every: 5,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg)?;
    t.init()?;
    let outcome = t.train()?;
    println!("\nquickstart run:");
    println!("  loss  {:.3} → {:.3}", t.metrics.steps[0].loss, outcome.final_loss);
    println!("  val perplexity {:.1}", outcome.final_perplexity);
    println!("  mean step {:.0} ms (coordinator overhead {:.2}%)",
             outcome.mean_step_ms, outcome.coordinator_overhead * 100.0);
    println!("quickstart OK");
    Ok(())
}
