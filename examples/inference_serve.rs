//! Batched-inference serving example: the deployment story for a
//! SLoPe-pretrained model.
//!
//! Restores a checkpoint (or fresh-initializes), then serves a stream of
//! generation requests through the AOT `forward`/`forward_lora`
//! executable with dynamic batching: requests arrive on a queue, the
//! server coalesces up to `batch_size` of them per forward, and reports
//! per-request latency (p50/p95) and token throughput — the serving-side
//! counterpart of the paper's inference-speedup claims (Table 2).
//!
//! The batcher's staging buffers are allocated once and reused for every
//! coalesced batch (allocation-free steady state), and the kernel-engine
//! thread count is configurable:
//!
//! ```bash
//! cargo run --release --example inference_serve -- [n_requests] [model] [threads]
//! ```

use slope::backend::ParallelPolicy;
use slope::config::{Method, RunConfig};
use slope::coordinator::Trainer;
use slope::data::{Corpus, CorpusSpec};
use std::collections::VecDeque;
use std::time::Instant;

struct Request {
    id: usize,
    tokens: Vec<i32>, // (seq,) prompt
    submitted: Instant,
}

fn main() -> slope::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(64);
    let model = args.get(1).cloned().unwrap_or_else(|| "gpt-nano".to_string());
    let threads: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0);

    // Warm up a model: a short training run gives us non-random weights.
    let cfg = RunConfig {
        model: model.clone(),
        method: Method::Slope,
        steps: 8,
        lazy_fraction: 0.25,
        eval_every: 1000,
        parallel: ParallelPolicy::with_threads(threads),
        ..Default::default()
    };
    let mut t = Trainer::new(cfg)?;
    t.init()?;
    t.train()?;
    let c = t.manifest.config.clone();
    let (b, s) = (c.batch_size, c.seq_len);
    // The policy rides on RunConfig for the CPU kernel backend; the AOT
    // forward path this server drives is single-stream until the runtime
    // consumes it (ROADMAP "Policy into the AOT path").
    println!(
        "== inference_serve: {model} (batch {b}, seq {s}; policy {} thr, CPU kernels only) ==",
        t.cfg.parallel.effective_threads()
    );

    // Request source: prompts sliced from a held-out corpus.
    let corpus = Corpus::generate(CorpusSpec::for_vocab(c.vocab_size, 0xD15C));
    let mut queue: VecDeque<Request> = (0..n_requests)
        .map(|id| Request {
            id,
            tokens: corpus.val_batch(1, s - 1, id).tokens[..s].to_vec(),
            submitted: Instant::now(),
        })
        .collect();

    // Dynamic batcher: coalesce up to `b` requests per forward; pad the
    // tail batch by repeating the last request.  Staging buffers live
    // outside the loop — the steady-state batcher does not allocate.
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(n_requests);
    let mut served = 0usize;
    let mut batch_tokens: Vec<i32> = Vec::with_capacity(b * s);
    let mut ids: Vec<usize> = Vec::with_capacity(b);
    let mut submitted: Vec<Instant> = Vec::with_capacity(b);
    let t0 = Instant::now();
    while !queue.is_empty() {
        let take = queue.len().min(b);
        batch_tokens.clear();
        ids.clear();
        submitted.clear();
        for _ in 0..take {
            let r = queue.pop_front().unwrap();
            batch_tokens.extend_from_slice(&r.tokens);
            ids.push(r.id);
            submitted.push(r.submitted);
        }
        for _ in take..b {
            batch_tokens.extend_from_within(batch_tokens.len() - s..);
        }
        t.store.put_i32("tokens", &[b, s], &batch_tokens)?;
        t.session.borrow_mut().run("forward_lora", &mut t.store)?;
        let logits = t.store.read_f32("logits")?;
        // "Generation": greedy next token at the final position per request.
        let v = c.vocab_size;
        for (row, (_id, sub)) in ids.iter().zip(&submitted).enumerate().map(|(i, x)| (i, x)) {
            let off = row * s * v + (s - 1) * v;
            let next = logits[off..off + v]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            let _ = next;
            latencies_ms.push(sub.elapsed().as_secs_f64() * 1e3);
            served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| latencies_ms[((latencies_ms.len() - 1) as f64 * p) as usize];
    println!("served {served} requests in {wall:.2}s");
    println!("throughput : {:.1} req/s  ({:.0} tok/s prefill)",
             served as f64 / wall, (served * s) as f64 / wall);
    println!("latency    : p50 {:.0} ms   p95 {:.0} ms", q(0.50), q(0.95));
    println!("inference_serve OK");
    Ok(())
}
