//! Batched-inference serving example — a thin client of the
//! [`slope::serve`] subsystem, now built around the `ServeModel` trait:
//! the same engine/batcher/stats plumbing drives either a synthetic
//! kernel stack ([`slope::serve::KernelStackModel`]) or a checkpointed
//! transformer behind a manifest ([`slope::serve::AotModel`]).
//!
//! Default mode builds a nano-scale sparse MLP stack (2:4 weights +
//! rank-8 adapters — the Eq.-11 serving operand), submits a stream of
//! requests, and reports p50/p95/p99 latency and throughput — the
//! serving-side counterpart of the paper's inference-speedup claims
//! (Table 2).  Pass an artifact directory as the fourth argument to
//! serve a checkpointed model end-to-end instead (requests become token
//! sequences, responses next-token logits).
//!
//! ```bash
//! cargo run --release --example inference_serve -- [n_requests] [max_batch] [threads] [manifest_dir]
//! ```

use slope::backend::{ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::serve::{AotModel, BatchPolicy, LoraAdapter, ServeEngine, ServeLayer, ServeModel};
use slope::sparsity::{random_row_mask, NmScheme};
use slope::tensor::Matrix;
use slope::util::Rng;
use std::time::Duration;

/// Open-loop request stream via the engine's shared driver
/// ([`ServeEngine::run_open_loop`] — the same loop `slope serve` uses),
/// then report — generic over the serving backend.
fn drive<M, G>(eng: &mut ServeEngine<M>, n_requests: usize,
               mut make_input: G) -> slope::Result<()>
where
    M: ServeModel,
    G: FnMut(&mut Rng) -> Vec<f32>,
{
    println!("model      : {}", eng.model().describe());
    let mut rng = Rng::seed_from_u64(0x7AFF1C);
    let served = eng.run_open_loop(n_requests, || make_input(&mut rng))?;
    println!("{}", eng.stats().summary().report(served, eng.policy().max_batch));
    Ok(())
}

fn main() -> slope::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(64);
    let max_batch: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let threads: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0);
    let policy_batch = BatchPolicy::new(max_batch, Duration::from_millis(2));

    if let Some(dir) = args.get(3) {
        // Manifest mode: serve a checkpointed transformer (see
        // `slope train --checkpoint-dir` / `slope serve --manifest`).
        let dir = std::path::PathBuf::from(dir);
        let m = slope::runtime::Manifest::load(&dir)?;
        let (vocab, seq) = (m.config.vocab_size, m.config.seq_len);
        let policy = ParallelPolicy::for_width(threads, m.config.d_model);
        println!(
            "== inference_serve: manifest {} ({}); max_batch {max_batch}, {} thr ==",
            dir.display(),
            m.config.name,
            policy.effective_threads()
        );
        let model = AotModel::open(&dir, policy)?;
        let mut eng = ServeEngine::with_model(model, policy_batch)?;
        drive(&mut eng, n_requests, |rng| {
            (0..seq).map(|_| rng.below(vocab) as f32).collect()
        })?;
        println!("inference_serve OK");
        return Ok(());
    }

    // A nano-scale MLP block: upsample d→4d, downsample 4d→d, 2:4 sparse
    // + rank-8 LoRA — the Eq.-11 serving operand at example-friendly size.
    let (d, f, rank) = (256usize, 1024usize, 8usize);
    let policy = ParallelPolicy::for_width(threads, d);
    let mut rng = Rng::seed_from_u64(0xD15C);
    let mut layers = Vec::new();
    for (d_out, d_in) in [(f, d), (d, f)] {
        let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(), &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor, policy);
        let lora = LoraAdapter {
            up: Matrix::randn(d_out, rank, 0.1, &mut rng),
            down: Matrix::randn(rank, d_in, 0.1, &mut rng),
        };
        layers.push(ServeLayer::new(be, Some(lora))?);
    }
    let mut eng = ServeEngine::new(layers, policy_batch)?;
    println!(
        "== inference_serve: sparse MLP block ({d}↔{f}, 2:4 + rank-{rank} LoRA; \
         max_batch {max_batch}, {} thr) ==",
        policy.effective_threads()
    );
    drive(&mut eng, n_requests, |rng| {
        (0..d).map(|_| rng.normal_f32(0.5)).collect()
    })?;
    println!("inference_serve OK");
    Ok(())
}
