//! Batched-inference serving example — now a thin client of the
//! first-class [`slope::serve`] subsystem (`ServeEngine` + coalescing
//! `Batcher` + `ServeStats`), which owns the warm sparse+LoRA layers and
//! the dynamic-batching policy that used to live ad hoc in this file.
//!
//! Builds a nano-scale sparse MLP stack (2:4 weights + rank-8 adapters —
//! the Eq.-11 serving operand), submits a stream of requests,
//! and reports p50/p95 latency and throughput — the serving-side
//! counterpart of the paper's inference-speedup claims (Table 2).  With
//! the column-striped kernel partition even `batch = 1` traffic scales
//! with `threads` (see `benches/bench_serve.rs` for the sweep).
//!
//! ```bash
//! cargo run --release --example inference_serve -- [n_requests] [max_batch] [threads]
//! ```

use slope::backend::{ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::serve::{BatchPolicy, LoraAdapter, ServeEngine, ServeLayer};
use slope::sparsity::{random_row_mask, NmScheme};
use slope::tensor::Matrix;
use slope::util::Rng;
use std::time::{Duration, Instant};

fn main() -> slope::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(64);
    let max_batch: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let threads: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0);

    // A nano-scale MLP block: upsample d→4d, downsample 4d→d, 2:4 sparse
    // + rank-8 LoRA — the Eq.-11 serving operand at example-friendly size.
    let (d, f, rank) = (256usize, 1024usize, 8usize);
    let policy = ParallelPolicy::for_width(threads, d);
    let mut rng = Rng::seed_from_u64(0xD15C);
    let mut layers = Vec::new();
    for (d_out, d_in) in [(f, d), (d, f)] {
        let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(), &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor, policy);
        let lora = LoraAdapter {
            up: Matrix::randn(d_out, rank, 0.1, &mut rng),
            down: Matrix::randn(rank, d_in, 0.1, &mut rng),
        };
        layers.push(ServeLayer::new(be, Some(lora))?);
    }
    let mut eng = ServeEngine::new(
        layers,
        BatchPolicy::new(max_batch, Duration::from_millis(2)),
    )?;
    println!(
        "== inference_serve: sparse MLP block ({d}↔{f}, 2:4 + rank-{rank} LoRA; \
         max_batch {max_batch}, {} thr) ==",
        policy.effective_threads()
    );

    // Open-loop request stream: submit, poll (the engine coalesces under
    // its max_batch / max_wait policy), then drain the tail.
    let start = Instant::now();
    let mut served = 0usize;
    for _ in 0..n_requests {
        let input: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.5)).collect();
        eng.submit(input, start.elapsed())?;
        served += eng.poll(start.elapsed()).len();
    }
    served += eng.flush(start.elapsed()).len();

    let s = eng.stats().summary();
    println!("served {served} requests in {} coalesced batches", s.batches);
    println!("batch fill : {:.2} / {max_batch}", s.mean_batch_fill);
    println!("throughput : {:.0} req/s", s.req_per_s);
    println!("latency    : p50 {:.3} ms   p95 {:.3} ms", s.p50_ms, s.p95_ms);
    println!("inference_serve OK");
    Ok(())
}
