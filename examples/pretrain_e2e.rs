//! End-to-end pretraining driver (DESIGN.md §"End-to-end validation").
//!
//! Trains a real small GPT through the FULL three-layer stack — Pallas N:M
//! kernels → JAX train step → AOT HLO → rust coordinator — on the synthetic
//! Zipf–Markov corpus, with the paper's phase schedule (sparse 2:4 for the
//! first (1−λ) of steps, lazy low-rank adapters for the final λ), logging
//! the loss curve, checkpointing, and reporting validation perplexity plus
//! the cloze probe.  The recorded run lives in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example pretrain_e2e -- [steps] [model]
//! # default: 300 steps of gpt-micro (~8.6M params, batch 8×128)
//! ```

use slope::config::{Method, RunConfig};
use slope::coordinator::{checkpoint, Trainer};

fn main() -> slope::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let model = args.get(1).cloned().unwrap_or_else(|| "gpt-micro".to_string());

    let cfg = RunConfig {
        model: model.clone(),
        method: Method::Slope,
        steps,
        lazy_fraction: 0.05, // scaled-up from the paper's 1% so the lazy
        // phase is visible at a few hundred steps
        eval_every: (steps / 10).max(1),
        eval_batches: 4,
        seed: 0,
        artifacts: "artifacts".into(),
        out_dir: "runs".into(),
        checkpoint_dir: None,
        resume: None,
        keep_checkpoints: 3,
        parallel: slope::backend::ParallelPolicy::auto(),
    };
    println!("== pretrain_e2e: {model}, {steps} steps, SLoPe 2:4 + lazy adapters ==");
    let mut t = Trainer::new(cfg)?;
    t.init()?;
    println!("model: ~{:.1}M dense params, vocab {}, seq {}, batch {}",
             t.manifest.config.n_params_dense as f64 / 1e6,
             t.manifest.config.vocab_size,
             t.manifest.config.seq_len,
             t.manifest.config.batch_size);
    println!("corpus entropy floor ≈ {:.2} nats (ppl {:.1})",
             t.corpus.entropy_floor(), t.corpus.entropy_floor().exp());

    let outcome = t.train()?;

    // Loss curve (downsampled).
    println!("\nloss curve:");
    let n = t.metrics.steps.len();
    for rec in t.metrics.steps.iter().step_by((n / 16).max(1)) {
        println!("  step {:>5}  loss {:.4}  [{}]", rec.step, rec.loss, rec.phase);
    }
    println!("\nvalidation perplexity:");
    for e in &t.metrics.evals {
        println!("  step {:>5}  ppl {:.2}", e.step, e.perplexity);
    }

    // Checkpoint the final model (params + masks + adapters).
    std::fs::create_dir_all("runs")?;
    let ckpt = std::path::PathBuf::from(format!("runs/{model}-e2e.slopeckpt"));
    let tensors = checkpoint::save(&t.store, &["params.", "masks.", "lora."], &ckpt)?;
    println!("\ncheckpointed {tensors} tensors → {}", ckpt.display());

    println!("\n== e2e summary ==");
    println!("final loss           : {:.4}", outcome.final_loss);
    println!("final val perplexity : {:.2}", outcome.final_perplexity);
    println!("cloze probe accuracy : {:.1}%", outcome.cloze_accuracy * 100.0);
    println!("mean step wall       : {:.0} ms", outcome.mean_step_ms);
    println!("coordinator overhead : {:.2}%", outcome.coordinator_overhead * 100.0);
    let first = t.metrics.steps.first().map(|s| s.loss).unwrap_or(f32::NAN);
    slope::ensure!(outcome.final_loss < first, "training must reduce the loss");
    println!("pretrain_e2e OK");
    Ok(())
}
