//! Ablation sweep driver: runs a compact version of the paper's accuracy
//! ablations (mixed N:M, module scope, pruning target) back-to-back and
//! prints a combined summary — handy for kicking the tires on all the
//! baseline paths without invoking the full experiment harness.
//!
//! ```bash
//! cargo run --release --example ablation_sweep -- [steps]
//! ```

use slope::config::{Fig9Variant, Method, RunConfig};
use slope::coordinator::Trainer;

fn run(model: &str, method: Method, steps: usize, label: &str) -> slope::Result<(f64, f64)> {
    let cfg = RunConfig {
        model: model.into(),
        method,
        steps,
        lazy_fraction: 0.1,
        eval_every: steps.max(1),
        eval_batches: 3,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg)?;
    t.init()?;
    let o = t.train()?;
    println!("{label:<36} ppl {:>8.2}   cloze {:>5.1}%",
             o.final_perplexity, o.cloze_accuracy * 100.0);
    Ok((o.final_perplexity, o.cloze_accuracy))
}

fn main() -> slope::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(40);
    println!("== ablation sweep ({steps} steps each) ==\n");

    println!("-- mixed N:M (Table 6 shape) --");
    let a = run("gpt-nano", Method::Slope, steps, "SLoPe 2:4-2:4")?;
    let b = run("gpt-nano-24-28", Method::Slope, steps, "SLoPe 2:4-2:8")?;
    let c = run("gpt-nano-28-24", Method::Slope, steps, "SLoPe 2:8-2:4")?;

    println!("\n-- module scope (Table 9 shape) --");
    run("gpt-nano", Method::Dense, steps, "Dense")?;
    run("gpt-nano-mlponly", Method::Slope, steps, "SLoPe MLP only")?;
    run("gpt-nano", Method::Slope, steps, "SLoPe MLP+attn")?;

    println!("\n-- pruning target (Figure 9 shape) --");
    run("gpt-nano", Method::Fig9(Fig9Variant::WeightStatic), steps, "weight static")?;
    run("gpt-nano", Method::Fig9(Fig9Variant::InputDynamic), steps, "input dynamic")?;

    println!("\nsanity: uniform 2:4 should not be worse than 2:8-heavy configs");
    println!("  2:4-2:4 {:.2} | 2:4-2:8 {:.2} | 2:8-2:4 {:.2}", a.0, b.0, c.0);
    println!("ablation_sweep OK");
    Ok(())
}
