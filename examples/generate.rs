//! KV-cached autoregressive generation, end to end and self-contained:
//! fabricate a synthetic serving artifact (manifest + packed checkpoint),
//! open it through [`slope::serve::AotModel`], and drive the
//! continuous-batching [`slope::serve::DecodeEngine`] — prompts prefill
//! into per-sequence KV caches, then share coalesced single-token decode
//! steps until EOS/max-tokens.  The decode analog of
//! `examples/inference_serve.rs`, and exactly what
//! `slope generate --manifest DIR` runs against a trained checkpoint.
//!
//! ```bash
//! cargo run --release --example generate -- [n_requests] [max_new_tokens] [threads]
//! ```

use slope::backend::ParallelPolicy;
use slope::runtime::{write_synthetic_artifact, SynthSpec};
use slope::serve::{AotModel, DecodeEngine, DecodeModel, DecodePolicy, Sampler};
use slope::util::Rng;
use std::time::Instant;

fn main() -> slope::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(6);
    let max_new: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);
    let threads: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0);

    // A synthetic artifact with room to generate (seq_len 48).
    let dir = std::env::temp_dir().join("slope_example_generate");
    let spec = SynthSpec {
        name: "example-generate".into(),
        vocab: 192,
        n_layer: 2,
        n_head: 4,
        d_model: 48,
        d_ff: 96,
        seq_len: 48,
        batch_size: 8,
        rank: 4,
        seed: 0xE7,
    };
    write_synthetic_artifact(&dir, &spec)?;

    let policy = ParallelPolicy::for_width(threads, spec.d_model);
    let model = AotModel::open(&dir, policy)?;
    println!("== generate: {} ==", model.describe_decode());

    let mut eng = DecodeEngine::new(
        model,
        DecodePolicy {
            max_batch: 4,
            max_new_tokens: max_new,
            eos: None,
            sampler: Sampler::Greedy,
            seed: 7,
            queue_cap: None,
        },
    )?;
    let mut rng = Rng::seed_from_u64(0x9E4);
    let start = Instant::now();
    for _ in 0..n_requests {
        let plen = rng.range(2, 9);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(spec.vocab) as i32).collect();
        eng.submit(prompt, None, start.elapsed())?;
    }
    let mut done = eng.run_to_completion(start)?;
    done.sort_by_key(|g| g.id);
    for g in &done {
        let toks: Vec<String> = g.tokens.iter().map(|t| t.to_string()).collect();
        println!(
            "gen {:>2}  prompt[{:>2}] +{:<3} {:<11} {}",
            g.id,
            g.prompt_len,
            g.tokens.len(),
            format!("{:?}", g.finish),
            toks.join(" ")
        );
    }
    println!("{}", eng.stats().summary().report(done.len(), eng.policy().max_batch));
    std::fs::remove_dir_all(&dir).ok();
    println!("generate OK");
    Ok(())
}
