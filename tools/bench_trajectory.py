#!/usr/bin/env python3
"""Perf-trajectory tooling for the bench-smoke CI job.

The benches emit one JSON object per line when ``SLOPE_BENCH_JSON`` is set
(``{bench, case, threads, median_ns, p10_ns, p90_ns, iters}``).  This tool

* ``archive`` — validates the rows and writes them as ``BENCH_<sha>.json``
  (a single JSON document with a timestamp) into the trajectory directory;
* ``compare`` — diffs the freshest archived trajectory (excluding the
  current sha) against the new rows and reports regressions where
  ``median_ns`` grew by more than ``--threshold`` (default 20%).

``compare`` is **fail-soft** by default: regressions are printed as GitHub
``::warning::`` annotations and the exit code stays 0 — CI-runner noise on
shared hardware must not gate kernel PRs; the archived trajectory is the
durable record.  Pass ``--hard`` to turn regressions into a non-zero exit.

Usage (what .github/workflows/ci.yml runs):
    python3 tools/bench_trajectory.py archive --json bench-smoke.jsonl \
        --sha "$GITHUB_SHA" --dir rust/bench-history
    python3 tools/bench_trajectory.py compare --json bench-smoke.jsonl \
        --sha "$GITHUB_SHA" --dir rust/bench-history
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

REQUIRED = {"bench", "case", "threads", "median_ns", "p10_ns", "p90_ns", "iters"}


def load_rows(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            row = json.loads(line)
            missing = REQUIRED - set(row)
            if missing:
                raise SystemExit(f"bench row missing {sorted(missing)}: {row}")
            if row["median_ns"] <= 0 or row["threads"] < 1:
                raise SystemExit(f"implausible bench row: {row}")
            rows.append(row)
    if not rows:
        raise SystemExit(f"{path}: no bench rows emitted")
    return rows


def key(row: dict) -> tuple:
    return (row["bench"], row["case"], row["threads"])


def archive(args) -> int:
    rows = load_rows(args.json)
    os.makedirs(args.dir, exist_ok=True)
    doc = {
        "sha": args.sha,
        "generated_unix": int(time.time()),
        "rows": sorted(rows, key=key),
    }
    if getattr(args, "synthetic", False):
        # A schema-only seed document: it proves the expected series shape
        # and lets the validators run on machines that cannot bench, but
        # its timings are placeholders — `compare` skips synthetic docs so
        # they never poison a real trajectory.
        doc["synthetic"] = True
    out = os.path.join(args.dir, f"BENCH_{args.sha}.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
    threads = sorted({r["threads"] for r in rows})
    print(f"archived {len(rows)} rows (threads {threads}) -> {out}")
    benches = sorted({r["bench"] for r in rows})
    if not {1, 2, 4} <= set(threads):
        raise SystemExit(f"expected a threads sweep, got {threads}")
    print(f"benches in trajectory: {benches}")
    # bench_serve must record ALL THREE serving series: the kernel-stack
    # cases keep their pre-redesign names (batch{B}/forward) so the
    # trajectory stays continuous, the manifest-backed AotModel series is
    # prefixed (manifest/batch{B}/forward), and the KV-cached per-token
    # decode series (decode/batch{B}/step) guards the autoregressive
    # hot path the same way.
    serve_cases = {r["case"] for r in rows if r["bench"] == "bench_serve"}
    if not serve_cases:
        raise SystemExit(
            "no bench_serve rows in the smoke run — the trajectory must carry "
            "the kernel-stack, manifest, and decode serving series"
        )
    kernel = {c for c in serve_cases if c.startswith("batch")}
    manifest = {c for c in serve_cases if c.startswith("manifest/")}
    decode = {c for c in serve_cases if c.startswith("decode/")}
    if not kernel or not manifest or not decode:
        raise SystemExit(
            "bench_serve must emit the kernel-stack (batch*/...), manifest "
            "(manifest/...), and decode (decode/...) series; "
            f"got {sorted(serve_cases)}"
        )
    # The paged KV pool adds a dtype axis (kv/<dtype>/batch{B}/step): the
    # trajectory must carry every plane storage so a quantization-path
    # regression (dequant-on-read, quantize-on-write) is attributable to
    # its dtype, not smeared into the plain decode series.
    kv = {c for c in serve_cases if c.startswith("kv/")}
    kv_dtypes = {c.split("/")[1] for c in kv if c.count("/") >= 2}
    if not {"f32", "f16", "int8"} <= kv_dtypes:
        raise SystemExit(
            "bench_serve must emit the paged KV dtype series "
            "(kv/f32|f16|int8/batch{B}/step); "
            f"got kv dtypes {sorted(kv_dtypes)}"
        )
    # The radix prefix cache adds an on/off pair (prefix/{on,off}/
    # batch{B}/step): both sides must be archived so a cache-path
    # regression is attributable — `on` drifting alone is a cache bug,
    # both drifting together is the prefill math.
    prefix = {c for c in serve_cases if c.startswith("prefix/")}
    prefix_modes = {c.split("/")[1] for c in prefix if c.count("/") >= 2}
    if not {"on", "off"} <= prefix_modes:
        raise SystemExit(
            "bench_serve must emit the prefix-cache pair "
            "(prefix/on|off/batch{B}/step); "
            f"got prefix modes {sorted(prefix_modes)}"
        )
    print(f"bench_serve series: {len(kernel)} kernel-stack, {len(manifest)} manifest, "
          f"{len(decode)} decode, {len(kv)} kv-dtype, {len(prefix)} prefix-cache")
    # bench_train guards the native training hot path the same way: both
    # the sparse-phase and the lazy-phase step series must be present.
    train_cases = {r["case"] for r in rows if r["bench"] == "bench_train"}
    if not train_cases:
        raise SystemExit(
            "no bench_train rows in the smoke run — the trajectory must carry "
            "the host train/step and train_lora/step series"
        )
    if "train/step" not in train_cases or "train_lora/step" not in train_cases:
        raise SystemExit(
            "bench_train must emit both the train/step and train_lora/step "
            f"series; got {sorted(train_cases)}"
        )
    print(f"bench_train series: {sorted(train_cases)}")
    # bench_spmm must carry the SIMD level-split series: each shape's
    # kernel is measured once at the forced scalar level and once at the
    # auto-detected level (simd/<shape>/scalar + simd/<shape>/auto), so
    # a trajectory row is always attributable to the dispatch level that
    # produced it.  On non-AVX2 runners the two coincide numerically but
    # both rows must still exist.
    spmm_cases = {r["case"] for r in rows if r["bench"] == "bench_spmm"}
    simd_cases = {c for c in spmm_cases if c.startswith("simd/")}
    if not simd_cases:
        raise SystemExit(
            "no spmm simd/* rows in the smoke run — bench_spmm must emit the "
            "level-split series (simd/<shape>/scalar and simd/<shape>/auto)"
        )
    simd_scalar = {c for c in simd_cases if c.endswith("/scalar")}
    simd_auto = {c for c in simd_cases if c.endswith("/auto")}
    simd_prepacked = {c for c in simd_cases if c.endswith("/prepacked")}
    if not simd_scalar or not simd_auto or not simd_prepacked:
        raise SystemExit(
            "bench_spmm simd series must include .../scalar, .../auto and "
            f".../prepacked cases per shape; got {sorted(simd_cases)}"
        )
    print(
        f"bench_spmm simd series: {len(simd_scalar)} scalar, "
        f"{len(simd_auto)} auto, {len(simd_prepacked)} prepacked"
    )
    return 0


def newest_baseline(dirname: str, exclude_sha: str):
    best = None
    if not os.path.isdir(dirname):
        return None
    for fname in os.listdir(dirname):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        try:
            with open(os.path.join(dirname, fname)) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"::warning::unreadable trajectory file {fname}: {e}")
            continue
        if doc.get("sha") == exclude_sha:
            continue
        if doc.get("synthetic"):
            # Schema-only seed archives carry placeholder timings — never
            # a comparison baseline.
            continue
        if best is None or doc.get("generated_unix", 0) > best.get("generated_unix", 0):
            best = doc
    return best


def compare(args) -> int:
    rows = {key(r): r for r in load_rows(args.json)}
    base = newest_baseline(args.dir, args.sha)
    if base is None:
        print("no prior trajectory to compare against (first archived run)")
        return 0
    baseline = {key(r): r for r in base["rows"]}
    regressions, improvements, compared = [], 0, 0
    for k, row in sorted(rows.items()):
        old = baseline.get(k)
        if old is None:
            continue
        compared += 1
        ratio = row["median_ns"] / old["median_ns"]
        if ratio > 1.0 + args.threshold:
            regressions.append((k, old["median_ns"], row["median_ns"], ratio))
        elif ratio < 1.0 - args.threshold:
            improvements += 1
    print(f"compared {compared} cases against {base['sha'][:12]} "
          f"({improvements} improved beyond the threshold)")
    for (bench, case, thr), old_ns, new_ns, ratio in regressions:
        print(f"::warning::perf regression {bench}/{case} t={thr}: "
              f"{old_ns / 1e3:.1f}us -> {new_ns / 1e3:.1f}us ({ratio:.2f}x)")
    if regressions and args.hard:
        return 1
    if regressions:
        print(f"{len(regressions)} regression(s) flagged fail-soft "
              f"(>{args.threshold:.0%} vs stored trajectory)")
    else:
        print("no regressions beyond threshold")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, fn in [("archive", archive), ("compare", compare)]:
        p = sub.add_parser(name)
        p.add_argument("--json", required=True, help="bench JSONL emitted by the smoke run")
        p.add_argument("--sha", required=True, help="current commit sha")
        p.add_argument("--dir", required=True, help="trajectory directory (BENCH_<sha>.json)")
        if name == "archive":
            p.add_argument("--synthetic", action="store_true",
                           help="mark the archive as a schema-only seed (placeholder "
                                "timings; skipped as a compare baseline)")
        if name == "compare":
            p.add_argument("--threshold", type=float, default=0.20,
                           help="relative median_ns growth flagged as regression")
            p.add_argument("--hard", action="store_true",
                           help="exit non-zero on regressions (default: fail-soft)")
        p.set_defaults(fn=fn)
    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
