"""Model and run configurations shared by the L2 model and the AOT exporter.

Each :class:`ModelConfig` describes a GPT-style decoder-only transformer with
SLoPe sparse linear layers.  The rust coordinator consumes the same configs
via the ``manifest.json`` emitted by ``aot.py``; keep this file the single
source of truth for the scaled-down model zoo used in accuracy experiments
(the full-size OPT/LLaMA/Mistral shape inventories used by the performance
and memory models live on the rust side in ``rust/src/config/zoo.rs``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """N:M sparsity scheme for a group of transformer blocks.

    ``n``/``m``: keep at most ``n`` non-zeros out of every ``m`` consecutive
    elements along the reduction dimension.  SLoPe default is 2:4.
    """

    n: int = 2
    m: int = 4

    @property
    def density(self) -> float:
        return self.n / self.m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A GPT-style decoder with per-block-group N:M sparsity.

    ``first_half_sparsity`` applies to blocks ``[0, n_layer/2)`` and
    ``second_half_sparsity`` to the rest — this expresses the paper's mixed
    N:M experiments (Table 6: 2:4-2:4 / 2:4-2:8 / 2:8-2:4).  ``prune_attn``
    and ``prune_mlp`` express the module-sensitivity ablation (Table 9).
    The embedding, the first linear after the input, and the LM head are
    always dense, matching §3.2 of the paper.
    """

    name: str = "gpt-nano"
    vocab_size: int = 512
    n_layer: int = 4
    n_head: int = 4
    d_model: int = 128
    d_ff: int = 512  # 4 * d_model (upsample/downsample aspect ratio 4)
    seq_len: int = 128
    batch_size: int = 8
    # Positional-embedding capacity; ≥ seq_len.  Lets two-phase (BERT-style)
    # runs share parameter shapes across phases with different seq_len.
    max_seq: int = 0
    first_half_sparsity: SparsityConfig = SparsityConfig(2, 4)
    second_half_sparsity: SparsityConfig = SparsityConfig(2, 4)
    prune_attn: bool = True
    prune_mlp: bool = True
    # Low-rank adapter rank used during the lazy phase (0 disables adapters).
    adapter_rank: int = 8
    tie_embeddings: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    @property
    def pos_len(self) -> int:
        return max(self.max_seq, self.seq_len)

    def sparsity_for_layer(self, layer: int) -> SparsityConfig:
        if layer < self.n_layer // 2:
            return self.first_half_sparsity
        return self.second_half_sparsity

    def n_params(self) -> int:
        """Approximate learnable parameter count (dense equivalent)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.n_layer
        per_block = 4 * d * d + 2 * d * f + 4 * d + 2 * f  # qkv+proj, up+down, ln+bias
        emb = v * d + self.seq_len * d
        head = 0 if self.tie_embeddings else v * d
        return emb + l * per_block + 2 * d + head


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer + schedule parameters consumed by the AOT train steps."""

    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 20
    total_steps: int = 1000
    # Fraction of iterations that run with lazy low-rank adapters (paper: 1%).
    lazy_fraction: float = 0.01
    # Extended SR-STE decay factor (gamma_w in Figure 2).
    srste_decay: float = 6e-6

    @property
    def lazy_steps(self) -> int:
        return max(1, int(round(self.total_steps * self.lazy_fraction)))


def _cfg(**kw) -> ModelConfig:
    return ModelConfig(**kw)


# Scaled-down model zoo (see DESIGN.md §6 for the scaling rationale).
MODEL_CONFIGS: Dict[str, ModelConfig] = {
    # ~2.2M params — the workhorse for ablation sweeps (Tables 4/6/9, Fig 2/9).
    "gpt-nano": _cfg(name="gpt-nano"),
    # ~8.6M params — the "large" partner for Figure 2's small/large pairing.
    "gpt-micro": _cfg(
        name="gpt-micro", n_layer=6, n_head=8, d_model=256, d_ff=1024, seq_len=128
    ),
    # ~27M params — e2e example scale (pretrain_e2e), proves the stack composes.
    "gpt-mini": _cfg(
        name="gpt-mini", n_layer=8, n_head=8, d_model=512, d_ff=2048,
        seq_len=256, batch_size=4, vocab_size=1024, adapter_rank=16,
    ),
    # BERT-phase stand-in: short-sequence phase-1 / long-sequence phase-2
    # (Table 5 / Figure 7 rank sweep uses these two).
    "bert-phase1": _cfg(
        name="bert-phase1", n_layer=4, n_head=4, d_model=128, d_ff=512,
        seq_len=64, max_seq=256, batch_size=16, adapter_rank=8,
    ),
    "bert-phase2": _cfg(
        name="bert-phase2", n_layer=4, n_head=4, d_model=128, d_ff=512,
        seq_len=256, batch_size=4, adapter_rank=8,
    ),
    # Adapter-rank sweep variants (Table 4 / Table 5): same shapes, only
    # the lazy-adapter rank differs (r/d: 2/128 = 1.56%, 8/128 = 6.25%,
    # 32/128 = 25%).
    "gpt-nano-r2": _cfg(name="gpt-nano-r2", adapter_rank=2),
    "bert-phase2-r2": _cfg(
        name="bert-phase2-r2", n_layer=4, n_head=4, d_model=128, d_ff=512,
        seq_len=256, batch_size=4, adapter_rank=2,
    ),
    "bert-phase2-r32": _cfg(
        name="bert-phase2-r32", n_layer=4, n_head=4, d_model=128, d_ff=512,
        seq_len=256, batch_size=4, adapter_rank=32,
    ),
    # Mixed-sparsity variants (Table 6).
    "gpt-nano-24-28": _cfg(
        name="gpt-nano-24-28", second_half_sparsity=SparsityConfig(2, 8)
    ),
    "gpt-nano-28-24": _cfg(
        name="gpt-nano-28-24", first_half_sparsity=SparsityConfig(2, 8)
    ),
    # Module-sensitivity variants (Table 9).
    "gpt-nano-mlponly": _cfg(name="gpt-nano-mlponly", prune_attn=False),
    # Depth/width pruning comparison (Figure 10 / Appendix S).
    "gpt-nano-half-depth": _cfg(name="gpt-nano-half-depth", n_layer=2),
    "gpt-nano-half-width": _cfg(name="gpt-nano-half-width", d_ff=256),
}


TRAIN_CONFIGS: Dict[str, TrainConfig] = {
    "default": TrainConfig(),
    "short": TrainConfig(total_steps=200, warmup_steps=10),
    "e2e": TrainConfig(total_steps=400, warmup_steps=20, lazy_fraction=0.05),
}


def get_model_config(name: str) -> ModelConfig:
    try:
        return MODEL_CONFIGS[name]
    except KeyError as e:
        raise KeyError(f"unknown model config {name!r}; have {sorted(MODEL_CONFIGS)}") from e


def get_train_config(name: str) -> TrainConfig:
    try:
        return TRAIN_CONFIGS[name]
    except KeyError as e:
        raise KeyError(f"unknown train config {name!r}; have {sorted(TRAIN_CONFIGS)}") from e
