"""L1 perf analysis: VMEM footprint + MXU-utilization *estimates* for the
Pallas kernels' BlockSpec schedules (DESIGN.md §8).

interpret=True gives CPU-numpy timing only — NOT a TPU proxy — so the L1
optimization loop works on structure: tile shapes vs the 16 MiB VMEM
budget, MXU (128×128 systolic) occupancy of each dot, and the HBM↔VMEM
traffic each BlockSpec implies.  Run:

    cd python && python -m compile.perf_report
"""

from __future__ import annotations

import dataclasses

from .configs import MODEL_CONFIGS
from .kernels.matmul import pick_block, pick_blocks, vmem_elems, MXU_EDGE

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5e per-core VMEM
F32 = 4


@dataclasses.dataclass
class KernelPlan:
    name: str
    m: int
    n: int
    k: int

    #: use the pre-iteration-1 (128-edge) plan for the before/after table.
    legacy: bool = False

    @property
    def blocks(self):
        if self.legacy:
            return (pick_block(self.m, 128), pick_block(self.n, 128),
                    pick_block(self.k, 128))
        return pick_blocks(self.m, self.n, self.k)

    def vmem_bytes(self) -> int:
        bm, bn, bk = self.blocks
        # + double-buffered input tiles (Mosaic pipelines HBM→VMEM copies).
        base = vmem_elems(bm, bn, bk)
        double_buf = bm * bk + bk * bn
        return (base + double_buf) * F32

    def mxu_utilization(self) -> float:
        """Tile-quantization utilization of the 128×128 MXU per dot: how
        full the systolic array is for the chosen block shapes."""
        bm, bn, bk = self.blocks
        fill = lambda d: min(d, MXU_EDGE) / MXU_EDGE
        return fill(bm) * fill(bn)

    def hbm_traffic_ratio(self) -> float:
        """Actual HBM reads / minimal one-pass reads for the (m,n,k) grid:
        >1 means operand re-streaming across grid steps."""
        bm, bn, bk = self.blocks
        gm, gn, gk = self.m // bm, self.n // bn, self.k // bk
        # x tile read once per (i, kk) per j; w tile once per (j, kk) per i.
        actual = gm * gk * gn * bm * bk + gn * gk * gm * bk * bn
        minimal = self.m * self.k + self.k * self.n
        return actual / minimal


def report(cfg_name: str) -> None:
    cfg = MODEL_CONFIGS[cfg_name]
    t = cfg.batch_size * cfg.seq_len
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    plans = [
        KernelPlan("spmm qkv (fwd)", t, 3 * d, d),
        KernelPlan("spmm proj (fwd)", t, d, d),
        KernelPlan("spmm up (fwd)", t, f, d),
        KernelPlan("spmm down (fwd)", t, d, f),
        KernelPlan("spmm bwd2 up", t, d, f),
        KernelPlan("matmul gradW up", f, d, t),
        KernelPlan("lm head", t, v, d),
    ]
    print(f"\n== {cfg.name}: batch·seq = {t}, d = {d}, ffn = {f} ==")
    legacy_reread = sum(KernelPlan(p.name, p.m, p.n, p.k, legacy=True).hbm_traffic_ratio()
                        for p in plans) / len(plans)
    new_reread = sum(p.hbm_traffic_ratio() for p in plans) / len(plans)
    print(f"   mean HBM re-read: {legacy_reread:.1f}x (128-tiles) → {new_reread:.1f}x (current)")
    print(f"{'kernel':<20} {'blocks':<16} {'VMEM':>10} {'of 16MiB':>9} "
          f"{'MXU util':>9} {'HBM re-read':>12}")
    worst = 0.0
    for p in plans:
        vb = p.vmem_bytes()
        worst = max(worst, vb / VMEM_BYTES)
        print(f"{p.name:<20} {str(p.blocks):<16} {vb/1024:>8.0f}KiB "
              f"{vb/VMEM_BYTES:>8.1%} {p.mxu_utilization():>9.1%} "
              f"{p.hbm_traffic_ratio():>11.1f}x")
    assert worst <= 1.0, "VMEM budget exceeded — shrink blocks"


def main() -> None:
    for name in ("gpt-nano", "gpt-micro", "gpt-mini"):
        report(name)
    print("\nAll kernel plans fit VMEM with double buffering; MXU util is "
          "100% whenever the model dim ≥ 128 (nano's d=128 edge included).")


if __name__ == "__main__":
    main()
