"""L2: optimizer + AOT-exportable train/eval step builders.

Everything here is a pure function of explicit state so the lowered HLO has
a stable (state-in → state-out) signature the rust coordinator can drive:

    train_step(tokens, step, params, opt, masks[, lora, lora_opt])
        → (loss, params', opt'[, lora', lora_opt'])

The optimizer is AdamW with the sparse-aware semantics of Algorithm 1:
gradients arrive already masked (line 13, via the SLoPe custom VJP), the
weight-decay combine ``(1/γ)·∇W + α·W`` happens on the sparse support
(line 15, the ``sparseAdd`` kernel), and updates are re-masked so weights
never leave the static support (lines 17–18).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, TrainConfig
from .model import SPARSE_WEIGHTS, forward, lm_loss
from .sparsity import magnitude_nm_mask


# ---------------------------------------------------------------------------
# AdamW with masked updates
# ---------------------------------------------------------------------------

def init_opt_state(params: Dict) -> Dict:
    """First/second Adam moments (zeros) + scalar step counter."""
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.float32)}


def lr_schedule(tc: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to 10% of peak."""
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps) /
                    jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * prog)
    return tc.lr * warm * cos


_NO_DECAY_SUFFIXES = ("_g", "_b", "lnf_g", "lnf_b", "pos_emb")


def _decay_coeff(path: str, tc: TrainConfig) -> float:
    """Decoupled weight decay on matrices only (standard GPT recipe)."""
    leaf = path.split(".")[-1]
    if leaf.startswith("b") or leaf.endswith("_g") or leaf.endswith("_b"):
        return 0.0
    if leaf in ("pos_emb",):
        return 0.0
    return tc.weight_decay


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return ".".join(parts)


def adamw_update(tc: TrainConfig, params: Dict, grads: Dict, opt: Dict,
                 update_masks: Optional[Dict] = None) -> Tuple[Dict, Dict]:
    """One AdamW step.  ``update_masks`` (same pytree as ``params``, or None
    per-leaf) constrains a leaf's update to the sparse support — the
    Algorithm-1 guarantee that pruned slots stay exactly zero and their
    optimizer state stays empty (memory model: 2×-reduced Adam moments)."""
    step = opt["step"] + 1.0
    lr = lr_schedule(tc, step)
    b1, b2, eps = tc.beta1, tc.beta2, tc.eps
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    flat_mask = (jax.tree_util.tree_leaves(update_masks, is_leaf=lambda x: x is None)
                 if update_masks is not None else [None] * len(flat_g))

    # Global-norm gradient clip.
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in flat_g) + 1e-12)
    clip = jnp.minimum(1.0, tc.grad_clip / gnorm)

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v, msk in zip(flat_p, flat_g, flat_m, flat_v, flat_mask):
        g = g * clip
        # Algorithm 1 line 15: weight-decay combine on the sparse support.
        wd = _decay_coeff(_path_str(path), tc)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p)
        if msk is not None:
            upd = upd * msk  # lines 17–18: update only stored non-zeros
            m = m * msk
            v = v * msk
        new_p.append(p - upd)
        new_m.append(m)
        new_v.append(v)

    params = jax.tree_util.tree_unflatten(treedef, new_p)
    opt = {"m": jax.tree_util.tree_unflatten(treedef, new_m),
           "v": jax.tree_util.tree_unflatten(treedef, new_v), "step": step}
    return params, opt


def update_masks_from(masks: Dict, params: Dict) -> Dict:
    """Per-parameter update masks: ``mask_r`` for sparse block weights,
    ``None`` (unconstrained) elsewhere."""
    def build(p):
        res = {}
        for k, v in p.items():
            if isinstance(v, dict):
                res[k] = build(v)
            else:
                res[k] = None
        return res

    res = build(params)
    for i, blk in masks["blocks"].items():
        for wname in SPARSE_WEIGHTS:
            res["blocks"][i][wname] = blk[wname + "_r"]
    return res


# ---------------------------------------------------------------------------
# Train steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """SLoPe sparse-phase step (the 99%): Eq. 4–6 through the custom VJP."""

    def step_fn(tokens, params, opt, masks):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, masks, tokens))(params)
        params, opt = adamw_update(tc, params, grads, opt,
                                   update_masks_from(masks, params))
        return loss, params, opt

    return step_fn


def make_train_step_lora(cfg: ModelConfig, tc: TrainConfig):
    """Lazy-adapter phase step (the final 1%): sparse weights AND adapters
    both train; adapter gradients are plain autodiff."""

    def step_fn(tokens, params, opt, masks, lora, lora_opt):
        def loss_fn(p, lo):
            return lm_loss(cfg, p, masks, tokens, lora=lo)

        loss, (gp, gl) = jax.value_and_grad(loss_fn, argnums=(0, 1))(params, lora)
        params, opt = adamw_update(tc, params, gp, opt,
                                   update_masks_from(masks, params))
        lora, lora_opt = adamw_update(tc, lora, gl, lora_opt)
        return loss, params, opt, lora, lora_opt

    return step_fn


def make_eval_step(cfg: ModelConfig, with_lora: bool = False):
    """Validation negative-log-likelihood (perplexity = exp(loss))."""

    if with_lora:
        def step_fn(tokens, params, masks, lora):
            return lm_loss(cfg, params, masks, tokens, lora=lora)
    else:
        def step_fn(tokens, params, masks):
            return lm_loss(cfg, params, masks, tokens)
    return step_fn


def make_forward(cfg: ModelConfig, with_lora: bool = False):
    """Inference logits (B, S, V) — the serving path; LoRA uses the fused
    Eq.-11 kernels inside ``slope_linear_lora``."""

    if with_lora:
        def fwd(tokens, params, masks, lora):
            return forward(cfg, params, masks, tokens, lora=lora)
    else:
        def fwd(tokens, params, masks):
            return forward(cfg, params, masks, tokens)
    return fwd


# ---------------------------------------------------------------------------
# Baseline: Extended SR-STE (dynamic magnitude masks + decay regularizer)
# ---------------------------------------------------------------------------

def make_train_step_srste(cfg: ModelConfig, tc: TrainConfig):
    """Extended SR-STE (Zhou et al. '21, extended by FST to Adam-family
    optimizers — Listing 2 of the paper).

    Dense weights are stored; every step a fresh magnitude N:M mask prunes
    the forward weight; the straight-through gradient additionally receives
    ``γ_w · (mask̄ ⊙ W)`` pushing pruned weights toward zero.  No update
    masking — the whole point of the comparison is that SR-STE spends budget
    updating weights that end up pruned (paper Fig. 4).
    """

    def loss_fn(params, tokens):
        # Rebuild masks from current magnitudes (dynamic, per-iteration).
        masks = {"blocks": {}}
        from .model import _is_pruned
        for i in range(cfg.n_layer):
            sp = cfg.sparsity_for_layer(i)
            blk = params["blocks"][str(i)]
            bm = {}
            for wname in SPARSE_WEIGHTS:
                if _is_pruned(cfg, i, wname):
                    mr = magnitude_nm_mask(blk[wname], sp.n, sp.m)
                else:
                    mr = jnp.ones_like(blk[wname])
                bm[wname + "_r"] = mr
                bm[wname + "_rc"] = mr  # STE path: same mask both directions
            masks["blocks"][str(i)] = bm

        # Straight-through: forward sees masked weights, grads flow dense.
        from .layers import ste_masked
        ste_params = jax.tree_util.tree_map(lambda x: x, params)
        for i in range(cfg.n_layer):
            blk = dict(ste_params["blocks"][str(i)])
            for wname in SPARSE_WEIGHTS:
                blk[wname] = ste_masked(blk[wname], masks["blocks"][str(i)][wname + "_r"])
            ste_params["blocks"][str(i)] = blk
        ones = _ones_masks(cfg, params)
        return lm_loss(cfg, ste_params, ones, tokens), masks

    def step_fn(tokens, params, opt):
        (loss, masks), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, tokens)
        # SR-STE decay term: γ_w · (1 - mask) ⊙ W added to the gradient.
        for i in range(cfg.n_layer):
            gblk = dict(grads["blocks"][str(i)])
            for wname in SPARSE_WEIGHTS:
                mr = masks["blocks"][str(i)][wname + "_r"]
                w = params["blocks"][str(i)][wname]
                gblk[wname] = gblk[wname] + tc.srste_decay * (1.0 - mr) * w
            grads["blocks"][str(i)] = gblk
        params, opt = adamw_update(tc, params, grads, opt)
        return loss, params, opt

    return step_fn


def _ones_masks(cfg: ModelConfig, params: Dict) -> Dict:
    from .model import init_masks_like_ones
    return init_masks_like_ones(cfg, params)


def srste_mask_snapshot(cfg: ModelConfig, params: Dict) -> Dict:
    """Current magnitude masks of an SR-STE run — the rust coordinator
    differences consecutive snapshots to reproduce the Figure-4 mask-churn
    curve."""
    from .model import _is_pruned
    masks = {"blocks": {}}
    for i in range(cfg.n_layer):
        sp = cfg.sparsity_for_layer(i)
        blk = params["blocks"][str(i)]
        bm = {}
        for wname in SPARSE_WEIGHTS:
            if _is_pruned(cfg, i, wname):
                bm[wname] = magnitude_nm_mask(blk[wname], sp.n, sp.m)
            else:
                bm[wname] = jnp.ones_like(blk[wname])
        masks["blocks"][str(i)] = bm
    return masks


# ---------------------------------------------------------------------------
# Figure-9 ablation steps (choice of pruned matrix)
# ---------------------------------------------------------------------------

FIG9_VARIANTS = ("dense", "weight_static", "weight_dynamic", "input_static",
                 "input_dynamic", "gradout_dynamic")


def make_fig9_masks(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Static input-feature masks for the ``input_static`` variant: one N:M
    mask vector per linear input dimension."""
    dims = {"wqkv": cfg.d_model, "wproj": cfg.d_model,
            "wup": cfg.d_model, "wdown": cfg.d_ff}
    out = {"blocks": {}}
    keys = jax.random.split(key, cfg.n_layer)
    for i in range(cfg.n_layer):
        sp = cfg.sparsity_for_layer(i)
        sub = jax.random.split(keys[i], 4)
        out["blocks"][str(i)] = {
            wname + "_x": random_nm_mask_1d(sub[j], dims[wname], sp.n, sp.m)
            for j, wname in enumerate(SPARSE_WEIGHTS)
        }
    return out


def random_nm_mask_1d(key, d, n, m):
    from .sparsity import random_nm_mask
    return random_nm_mask(key, (1, d), n, m)[0]


def make_train_step_fig9(cfg: ModelConfig, tc: TrainConfig, variant: str):
    """Train step where the pruned matrix is chosen by ``variant``."""
    assert variant in FIG9_VARIANTS, variant

    def step_fn(tokens, params, opt, masks, fig9_masks):
        def loss_fn(p):
            return lm_loss(cfg, p, masks, tokens, fig9_variant=variant,
                           fig9_masks=fig9_masks)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd = update_masks_from(masks, params) if variant == "weight_static" else None
        params, opt = adamw_update(tc, params, grads, opt, upd)
        return loss, params, opt

    return step_fn
