"""L2: the SLoPe GPT model — init, forward, masks, adapters.

Parameters are plain nested dicts (pytrees) with stable, sorted keys so the
AOT flatten order is deterministic and recordable in ``manifest.json``.

Sparsity policy (paper §3.2): every linear inside the transformer blocks is
N:M-pruned *except* the first linear after the input (block 0's QKV), and
the embeddings / LM head are always dense.  ``prune_attn`` / ``prune_mlp``
gate the module-sensitivity ablation (Table 9); the per-half N:M schemes
come from the :class:`~compile.configs.ModelConfig` (Table 6).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .layers import (causal_attention, dense_linear, layer_norm, slope_linear,
                     slope_linear_lora, variant_linear)
from .sparsity import double_prune_mask, random_nm_mask

# Names of the sparse (prunable) weights inside each block.
SPARSE_WEIGHTS = ("wqkv", "wproj", "wup", "wdown")


def _winit(key, d_out, d_in, scale=0.02):
    return jax.random.normal(key, (d_out, d_in), jnp.float32) * scale


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Initialize all learnable parameters (dense values; masks separate)."""
    keys = jax.random.split(key, 2 + cfg.n_layer)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    params = {
        "tok_emb": jax.random.normal(keys[0], (v, d), jnp.float32) * 0.02,
        "pos_emb": jax.random.normal(keys[1], (cfg.pos_len, d), jnp.float32) * 0.01,
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
        "blocks": {},
    }
    for i in range(cfg.n_layer):
        bk = jax.random.split(keys[2 + i], 4)
        # Residual-branch projections scaled down by depth (GPT-2 style).
        proj_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layer)
        params["blocks"][str(i)] = {
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "wqkv": _winit(bk[0], 3 * d, d),
            "bqkv": jnp.zeros((3 * d,), jnp.float32),
            "wproj": _winit(bk[1], d, d, proj_scale),
            "bproj": jnp.zeros((d,), jnp.float32),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
            "wup": _winit(bk[2], f, d),
            "bup": jnp.zeros((f,), jnp.float32),
            "wdown": _winit(bk[3], d, f, proj_scale),
            "bdown": jnp.zeros((d,), jnp.float32),
        }
    if not cfg.tie_embeddings:
        params["head_w"] = _winit(keys[0], v, d)
    return params


def _is_pruned(cfg: ModelConfig, layer: int, wname: str) -> bool:
    if wname in ("wqkv", "wproj") and not cfg.prune_attn:
        return False
    if wname in ("wup", "wdown") and not cfg.prune_mlp:
        return False
    # First linear following the input stays dense (paper §3.2).
    if layer == 0 and wname == "wqkv":
        return False
    return True


def init_masks(cfg: ModelConfig, params: Dict, key: jax.Array,
               scheme: str = "random") -> Dict:
    """Build the static ``mask_r`` / ``mask_rc`` pair for every block weight.

    ``scheme``: ``random`` (SLoPe §2.1 — chosen at init, frozen forever) or
    ``magnitude`` (used when re-masking a trained dense model).  Non-pruned
    weights get all-ones masks so a single executable covers every ablation.
    """
    from .sparsity import magnitude_nm_mask

    masks = {"blocks": {}}
    keys = jax.random.split(key, cfg.n_layer)
    for i in range(cfg.n_layer):
        sp = cfg.sparsity_for_layer(i)
        blk = params["blocks"][str(i)]
        subkeys = jax.random.split(keys[i], len(SPARSE_WEIGHTS))
        bm = {}
        for j, wname in enumerate(SPARSE_WEIGHTS):
            w = blk[wname]
            if not _is_pruned(cfg, i, wname):
                bm[wname + "_r"] = jnp.ones_like(w)
                bm[wname + "_rc"] = jnp.ones_like(w)
                continue
            if scheme == "random":
                mr = random_nm_mask(subkeys[j], w.shape, sp.n, sp.m)
            elif scheme == "magnitude":
                mr = magnitude_nm_mask(w, sp.n, sp.m)
            else:
                raise ValueError(f"unknown mask scheme {scheme!r}")
            mrc = double_prune_mask(w, mr, sp.n, sp.m)
            bm[wname + "_r"] = mr
            bm[wname + "_rc"] = mrc
        masks["blocks"][str(i)] = bm
    return masks


def project_params(cfg: ModelConfig, params: Dict, masks: Dict) -> Dict:
    """Zero every pruned slot of the block weights (SLoPe stores weights
    sparsely — Algorithm 1 lines 3–4; the rust runtime asserts pruned slots
    are exactly 0 throughout training)."""
    out = jax.tree_util.tree_map(lambda x: x, params)
    for i in range(cfg.n_layer):
        blk = dict(out["blocks"][str(i)])
        for wname in SPARSE_WEIGHTS:
            blk[wname] = blk[wname] * masks["blocks"][str(i)][wname + "_r"]
        out["blocks"][str(i)] = blk
    return out


def init_lora(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Lazy low-rank adapters, one (L, R) pair per sparse block weight.

    Standard LoRA init: downsample R ~ N(0, 0.02²), upsample L = 0, so the
    adapter starts as an exact no-op when it is switched on at the 99% mark.
    """
    r = cfg.adapter_rank
    d, f = cfg.d_model, cfg.d_ff
    dims = {"wqkv": (3 * d, d), "wproj": (d, d), "wup": (f, d), "wdown": (d, f)}
    lora = {"blocks": {}}
    keys = jax.random.split(key, cfg.n_layer)
    for i in range(cfg.n_layer):
        subkeys = jax.random.split(keys[i], len(SPARSE_WEIGHTS))
        bl = {}
        for j, wname in enumerate(SPARSE_WEIGHTS):
            d_out, d_in = dims[wname]
            bl[wname + "_down"] = jax.random.normal(subkeys[j], (r, d_in), jnp.float32) * 0.02
            bl[wname + "_up"] = jnp.zeros((d_out, r), jnp.float32)
        lora["blocks"][str(i)] = bl
    return lora


def _block_linear(blk, masks_blk, lora_blk, x, wname, bname):
    """Dispatch one block linear through the sparse / sparse+LoRA path."""
    w, b = blk[wname], blk[bname]
    mr, mrc = masks_blk[wname + "_r"], masks_blk[wname + "_rc"]
    if lora_blk is None:
        return slope_linear(x, w, b, mr, mrc)
    return slope_linear_lora(x, w, b, mr, mrc,
                             lora_blk[wname + "_down"], lora_blk[wname + "_up"])


def forward(cfg: ModelConfig, params: Dict, masks: Dict, tokens: jnp.ndarray,
            lora: Optional[Dict] = None, capture_norms: bool = False,
            fig9_variant: Optional[str] = None, fig9_masks: Optional[Dict] = None):
    """Run the decoder; returns logits (B, S, V).

    ``capture_norms=True`` additionally returns the per-layer input-feature
    L2 norms needed for Wanda calibration.  ``fig9_variant`` routes every
    block linear through :func:`~compile.layers.variant_linear` instead of
    the SLoPe path (pruning-target ablation).
    """
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s, :]
    norms = {}
    for i in range(cfg.n_layer):
        blk = params["blocks"][str(i)]
        mblk = masks["blocks"][str(i)]
        lblk = None if lora is None else lora["blocks"][str(i)]
        h = layer_norm(x, blk["ln1_g"], blk["ln1_b"])
        if capture_norms:
            norms[f"blocks.{i}.wqkv"] = jnp.sqrt((h * h).sum((0, 1)))
        if fig9_variant is not None:
            sp = cfg.sparsity_for_layer(i)
            fm = fig9_masks["blocks"][str(i)] if fig9_masks else None
            qkv = variant_linear(h, blk["wqkv"], blk["bqkv"], fig9_variant,
                                 mblk["wqkv_r"],
                                 fm["wqkv_x"] if fm else None, sp.n, sp.m)
        else:
            qkv = _block_linear(blk, mblk, lblk, h, "wqkv", "bqkv")
        q, k, v = jnp.split(qkv, 3, axis=-1)
        att = causal_attention(q, k, v, cfg.n_head)
        if capture_norms:
            norms[f"blocks.{i}.wproj"] = jnp.sqrt((att * att).sum((0, 1)))
        if fig9_variant is not None:
            sp = cfg.sparsity_for_layer(i)
            fm = fig9_masks["blocks"][str(i)] if fig9_masks else None
            proj = variant_linear(att, blk["wproj"], blk["bproj"], fig9_variant,
                                  mblk["wproj_r"],
                                  fm["wproj_x"] if fm else None, sp.n, sp.m)
        else:
            proj = _block_linear(blk, mblk, lblk, att, "wproj", "bproj")
        x = x + proj
        h = layer_norm(x, blk["ln2_g"], blk["ln2_b"])
        if capture_norms:
            norms[f"blocks.{i}.wup"] = jnp.sqrt((h * h).sum((0, 1)))
        if fig9_variant is not None:
            sp = cfg.sparsity_for_layer(i)
            fm = fig9_masks["blocks"][str(i)] if fig9_masks else None
            up = variant_linear(h, blk["wup"], blk["bup"], fig9_variant,
                                mblk["wup_r"], fm["wup_x"] if fm else None,
                                sp.n, sp.m)
        else:
            up = _block_linear(blk, mblk, lblk, h, "wup", "bup")
        up = jax.nn.gelu(up)
        if capture_norms:
            norms[f"blocks.{i}.wdown"] = jnp.sqrt((up * up).sum((0, 1)))
        if fig9_variant is not None:
            sp = cfg.sparsity_for_layer(i)
            fm = fig9_masks["blocks"][str(i)] if fig9_masks else None
            down = variant_linear(up, blk["wdown"], blk["bdown"], fig9_variant,
                                  mblk["wdown_r"],
                                  fm["wdown_x"] if fm else None, sp.n, sp.m)
        else:
            down = _block_linear(blk, mblk, lblk, up, "wdown", "bdown")
        x = x + down
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    head_w = params["tok_emb"] if cfg.tie_embeddings else params["head_w"]
    logits = dense_linear(x, head_w, jnp.zeros((cfg.vocab_size,), x.dtype))
    if capture_norms:
        return logits, norms
    return logits


def lm_loss(cfg: ModelConfig, params: Dict, masks: Dict, tokens: jnp.ndarray,
            lora: Optional[Dict] = None, **fwd_kw) -> jnp.ndarray:
    """Causal LM cross-entropy.  ``tokens``: (B, S+1) int32; the model sees
    ``tokens[:, :-1]`` and predicts ``tokens[:, 1:]``."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, masks, inp, lora=lora, **fwd_kw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def wanda_calibration(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray) -> Dict:
    """One calibration forward pass returning per-layer activation norms
    (dense masks) for Wanda one-shot pruning."""
    ones = jax.tree_util.tree_map(jnp.ones_like, init_masks_like_ones(cfg, params))
    _, norms = forward(cfg, params, ones, tokens, capture_norms=True)
    return norms


def init_masks_like_ones(cfg: ModelConfig, params: Dict) -> Dict:
    """All-ones mask pytree (dense baseline / calibration)."""
    masks = {"blocks": {}}
    for i in range(cfg.n_layer):
        blk = params["blocks"][str(i)]
        bm = {}
        for wname in SPARSE_WEIGHTS:
            bm[wname + "_r"] = jnp.ones_like(blk[wname])
            bm[wname + "_rc"] = jnp.ones_like(blk[wname])
        masks["blocks"][str(i)] = bm
    return masks


def wanda_masks(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray) -> Dict:
    """Wanda one-shot N:M masks from a trained model + calibration batch."""
    from .sparsity import wanda_nm_mask

    norms = wanda_calibration(cfg, params, tokens)
    masks = {"blocks": {}}
    for i in range(cfg.n_layer):
        sp = cfg.sparsity_for_layer(i)
        blk = params["blocks"][str(i)]
        bm = {}
        for wname in SPARSE_WEIGHTS:
            w = blk[wname]
            if not _is_pruned(cfg, i, wname):
                bm[wname + "_r"] = jnp.ones_like(w)
                bm[wname + "_rc"] = jnp.ones_like(w)
                continue
            mr = wanda_nm_mask(w, norms[f"blocks.{i}.{wname}"], sp.n, sp.m)
            bm[wname + "_r"] = mr
            bm[wname + "_rc"] = double_prune_mask(w, mr, sp.n, sp.m)
        masks["blocks"][str(i)] = bm
    return masks
