"""L1 workhorse: Pallas tiled matmul kernels.

These kernels express the HBM↔VMEM schedule the paper implemented with
cuSPARSELt threadblocks as Pallas ``BlockSpec`` grids (see DESIGN.md
§Hardware-Adaptation).  All kernels run with ``interpret=True`` so they
lower to plain HLO and execute on the CPU PJRT client; on a real TPU the
same BlockSpecs drive the Mosaic pipeline.

Tile-size policy mirrors the paper's §2.4 finding that *square* tiles keep
the sparse backend in its high-efficiency regime: :func:`pick_block`
prefers the largest divisor ≤ the MXU edge (128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU systolic array edge on TPU; also the preferred square-tile edge.
MXU_EDGE = 128


def pick_block(dim: int, pref: int = MXU_EDGE) -> int:
    """Largest divisor of ``dim`` that is ≤ ``pref``, preferring powers of two."""
    if dim <= pref:
        return dim
    for cand in (pref, 256, 128, 64, 32, 16, 8, 4, 2):
        if dim % cand == 0 and cand <= pref:
            return cand
    return 1


# §Perf iteration 1 (see EXPERIMENTS.md §Perf/L1): 128-edge tiles used only
# 2.3% of VMEM while re-streaming operands 5–10×.  Growing the *output*
# tile to 256 (keeping bk = 128) quarters the cross-grid HBM re-reads at
# ~1 MiB VMEM — still far inside budget, and every dot stays a whole
# multiple of the 128×128 MXU.
OUT_TILE_PREF = 256


def pick_blocks(m: int, n: int, k: int) -> tuple:
    """Default (bm, bn, bk) for an (m, n, k) GEMM: 256-edge output tiles,
    128-deep reduction tiles, shrunk to divisors of the actual dims."""
    return pick_block(m, OUT_TILE_PREF), pick_block(n, OUT_TILE_PREF), pick_block(k)


def vmem_elems(bm: int, bn: int, bk: int) -> int:
    """VMEM working-set estimate (elements) for one (bm, bn, bk) program:
    x-tile + w-tile + out-tile + f32 scratch accumulator."""
    return bm * bk + bk * bn + 2 * bm * bn


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_tiles: int):
    """Grid (m, n, k): accumulate ``x_tile @ w_tile`` into a VMEM scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == k_tiles - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_blocked(x: jnp.ndarray, w: jnp.ndarray, *, bm: int = 0, bn: int = 0, bk: int = 0):
    """``x @ w`` with an (M, N, K) Pallas grid and a VMEM f32 accumulator.

    ``x``: (M, K), ``w``: (K, N).  Block sizes default to :func:`pick_block`
    of each dimension (full-dim single tile for the small models used in
    accuracy experiments, multi-tile for kernel tests and large shapes).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    dbm, dbn, dbk = pick_blocks(m, n, k)
    bm, bn, bk = bm or dbm, bn or dbn, bk or dbk
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape, bm, bn, bk)
    k_tiles = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, k_tiles=k_tiles),
        grid=(m // bm, n // bn, k_tiles),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w)


def _mm_add_kernel(x_ref, w_ref, c_ref, o_ref, acc_ref, *, k_tiles: int):
    """Fused ``x @ w + c`` — the cuBLAS fused matmul+add of §2.4, as one
    Pallas body: the addend tile is consumed inside the same program, so the
    sum never round-trips through HBM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == k_tiles - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_add_blocked(x: jnp.ndarray, w: jnp.ndarray, c: jnp.ndarray, *, bm: int = 0,
                       bn: int = 0, bk: int = 0):
    """Fused ``x @ w + c`` (``c``: (M, N)).  Used by the SpMM+LoRA fusion
    (Eq. 11 right: ``Y = Y2·R + Y1``)."""
    m, k = x.shape
    _, n = w.shape
    assert c.shape == (m, n), (c.shape, m, n)
    dbm, dbn, dbk = pick_blocks(m, n, k)
    bm, bn, bk = bm or dbm, bn or dbn, bk or dbk
    k_tiles = k // bk
    return pl.pallas_call(
        functools.partial(_mm_add_kernel, k_tiles=k_tiles),
        grid=(m // bm, n // bn, k_tiles),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w, c)


# ---------------------------------------------------------------------------
# Differentiable wrappers — pallas_call has no JVP rule, so the L2 model uses
# these custom-VJP versions whose gradients are themselves Pallas kernels.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Differentiable ``x @ w`` (auto-picked blocks)."""
    return matmul_blocked(x, w)


def _matmul_fwd(x, w):
    return matmul_blocked(x, w), (x, w)


def _matmul_bwd(res, gy):
    x, w = res
    return matmul_blocked(gy, w.T), matmul_blocked(x.T, gy)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@jax.custom_vjp
def matmul_add(x: jnp.ndarray, w: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Differentiable fused ``x @ w + c``."""
    return matmul_add_blocked(x, w, c)


def _matmul_add_fwd(x, w, c):
    return matmul_add_blocked(x, w, c), (x, w)


def _matmul_add_bwd(res, gy):
    x, w = res
    return matmul_blocked(gy, w.T), matmul_blocked(x.T, gy), gy


matmul_add.defvjp(_matmul_add_fwd, _matmul_add_bwd)
