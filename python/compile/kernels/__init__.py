"""L1 Pallas kernel library for SLoPe (see DESIGN.md §3/S4).

All kernels run under ``interpret=True`` so they lower to plain HLO and can
be executed by the CPU PJRT client from the rust runtime.
"""

from .matmul import (matmul, matmul_add, matmul_blocked, matmul_add_blocked,
                     pick_block, vmem_elems, MXU_EDGE)
from .nm_spmm import spmm_masked, spmm_compressed
from .lora import lora_forward_naive, lora_forward_fused, lora_forward_ref
from .prune_compress import apply_mask, prune_and_compress, sparse_add

__all__ = [
    "matmul", "matmul_add", "pick_block", "vmem_elems", "MXU_EDGE",
    "spmm_masked", "spmm_compressed",
    "lora_forward_naive", "lora_forward_fused", "lora_forward_ref",
    "apply_mask", "prune_and_compress", "sparse_add",
]
