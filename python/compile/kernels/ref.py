"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness contracts: pytest (``python/tests``) sweeps
shapes/dtypes with hypothesis and asserts ``assert_allclose(kernel, ref)``.
Keep these boring — no pallas, no tiling, just the math.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, w):
    return x @ w


def matmul_add_ref(x, w, c):
    return x @ w + c


def spmm_masked_ref(x, w, mask):
    """Eq. 4: ``Y = X · (W ⊙ mask)ᵀ``."""
    return x @ (w * mask).T


def spmm_compressed_ref(x, values, indices, d_in):
    """Decompress-then-matmul oracle for the compressed layout."""
    d_out = values.shape[0]
    w = jnp.zeros((d_out, d_in), values.dtype)
    rows = jnp.arange(d_out)[:, None]
    w = w.at[rows, indices].add(values)
    return x @ w.T


def lora_ref(x, w, mask, lora_l, lora_r):
    """Eq. 10/11: ``Y = X·(W⊙mask)ᵀ + X·Rᵀ·Lᵀ``."""
    return x @ (w * mask).T + (x @ lora_r.T) @ lora_l.T


def apply_mask_ref(g, mask):
    return g * mask


def prune_and_compress_ref(g, indices):
    return jnp.take_along_axis(g, indices, axis=1)


def sparse_add_ref(a, b, beta, gamma):
    return beta * a + gamma * b


def slope_linear_ref(x, w, mask_r, mask_rc, gy):
    """Full Eq. 4–6 contract for one linear layer.

    Returns ``(y, gx, gw)`` where the forward uses the row mask, grad-x uses
    the double-pruned mask, and grad-w is masked to the row mask's support
    (Algorithm 1 line 13).
    """
    y = x @ (w * mask_r).T
    gx = gy @ (w * mask_rc)
    gw = (gy.T @ x) * mask_r
    return y, gx, gw
