"""L1: fused SpMM + low-rank-adapter kernels (§2.4, Eq. 11).

A naive adapter needs four kernel launches per linear layer:

    Y1 = X · Wᵀ        (sparse GEMM)
    T  = X · Rᵀ        (downsample, rank-r)
    Y2 = T · Lᵀ        (upsample)
    Y  = Y1 + Y2       (add)

The paper fuses this to two launches (Eq. 11): the *downsample* factor is
concatenated onto the sparse weight so one GEMM emits ``[Y1|T] = X·[Wᵀ|Rᵀ]``,
and the upsample multiply is fused with the final add
(``Y = T·Lᵀ + Y1``) via a fused matmul+add.  Note the paper writes the
decomposition as ``W_dense = W_sparse + L·R`` with ``L: (d_out, r)``,
``R: (r, d_in)`` so that ``Y = X·Wᵀ + (X·Rᵀ)·Lᵀ``.

:func:`lora_forward_naive` and :func:`lora_forward_fused` implement both so
the fusion ablation (paper Table 7 / Appendix D) is measurable: the fused
path issues 2 ``pallas_call``s instead of 4 and keeps the rank-``r``
intermediate at higher arithmetic intensity by amortizing it into the big
GEMM's tiles.
"""

from __future__ import annotations

import jax.numpy as jnp

from .matmul import matmul, matmul_add
from .nm_spmm import spmm_masked


def lora_forward_naive(x, w, mask, lora_l, lora_r):
    """Four-launch reference path: sparse GEMM + 2 low-rank GEMMs + add."""
    y1 = spmm_masked(x, w, mask)
    t = matmul(x, lora_r.T)  # (b, r)
    y2 = matmul(t, lora_l.T)  # (b, d_out)
    return y1 + y2


def lora_forward_fused(x, w, mask, lora_l, lora_r):
    """Two-launch fused path (Eq. 11).

    Launch 1: ``[Y1|T] = X · [ (W ⊙ mask)ᵀ | Rᵀ ]`` — the downsample factor
    rides along as extra output columns of the sparse GEMM (its mask columns
    are 1).  Launch 2: fused ``Y = T·Lᵀ + Y1``.
    """
    d_out = w.shape[0]
    r = lora_r.shape[0]
    # Stack [W; R] row-wise: (d_out + r, d_in); the R rows are dense.
    w_cat = jnp.concatenate([w, lora_r], axis=0)
    m_cat = jnp.concatenate([mask, jnp.ones_like(lora_r)], axis=0)
    y1t = spmm_masked(x, w_cat, m_cat)  # (b, d_out + r)
    y1, t = y1t[:, :d_out], y1t[:, d_out:]
    return matmul_add(t, lora_l.T, y1)


def lora_forward_ref(x, w, mask, lora_l, lora_r):
    """Pure-jnp oracle for both paths."""
    return x @ (w * mask).T + (x @ lora_r.T) @ lora_l.T
