"""L1: prune-and-compress and sparse-add kernels (Algorithm 1 lines 13/15).

These are the paper's custom CUDA helper kernels (Appendix K), re-thought
for the Pallas/TPU model:

* :func:`prune_and_compress` — mask a dense gradient with the static weight
  mask and pack the survivors into the compressed ``(d_out, d_in·N/M)``
  layout, so the optimizer never stores the ~``(1−N/M)`` known-zero slots
  (the paper's "50% extra zero values in the dense format").
* :func:`sparse_add` — ``β·A + γ·B`` over compressed *values* planes (the
  index metadata is shared because SLoPe masks are static), used for the
  weight-decay combine ``(1/γ)·∇W + α·W`` on line 15 of Algorithm 1.
* :func:`apply_mask` — plain masked copy (the "update sparse matrix"
  primitive when operating in masked-dense layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _mask_kernel(g_ref, m_ref, o_ref):
    o_ref[...] = g_ref[...] * m_ref[...]


def apply_mask(g: jnp.ndarray, mask: jnp.ndarray, *, bn: int = 0, bk: int = 0):
    """Element-wise ``g ⊙ mask`` as a tiled Pallas kernel."""
    n, k = g.shape
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)
    return pl.pallas_call(
        _mask_kernel,
        grid=(n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(g, mask)


def _gather_rows_kernel(g_ref, i_ref, o_ref):
    """Per-row gather: out[r, c] = g[r, idx[r, c]] (VPU gather on TPU)."""
    g = g_ref[...]
    idx = i_ref[...]
    o_ref[...] = jnp.take_along_axis(g, idx, axis=1)


def prune_and_compress(g: jnp.ndarray, indices: jnp.ndarray, *, bn: int = 0):
    """Pack the masked gradient into the compressed values plane.

    ``indices``: (d_out, d_in·N/M) absolute column indices from the static
    weight mask (``compile.sparsity.compress_nm``).  Output has the same
    shape as ``indices`` — the gradient restricted to surviving slots.
    """
    n, k = g.shape
    kc = indices.shape[1]
    bn = bn or pick_block(n)
    return pl.pallas_call(
        _gather_rows_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn, kc), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, kc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, kc), g.dtype),
        interpret=True,
    )(g, indices)


def _sparse_add_kernel(a_ref, b_ref, o_ref, *, beta: float, gamma: float):
    o_ref[...] = beta * a_ref[...] + gamma * b_ref[...]


def sparse_add(a: jnp.ndarray, b: jnp.ndarray, beta: float, gamma: float,
               *, bn: int = 0, bk: int = 0):
    """``β·A + γ·B`` on compressed values planes with identical sparsity
    pattern (Algorithm 1 line 15; the paper's custom sparse-add CUDA
    kernel).  Also valid on masked-dense tensors."""
    assert a.shape == b.shape
    n, k = a.shape
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)
    return pl.pallas_call(
        functools.partial(_sparse_add_kernel, beta=beta, gamma=gamma),
        grid=(n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b)
