"""L1: N:M structured-sparse matmul kernels (the paper's cuSPARSELt role).

Two layouts are provided:

* :func:`spmm_masked` — weights kept dense with a 0/1 N:M mask applied in
  VMEM right before the MXU dot.  This is the layout used inside the AOT
  train steps (the mask is a runtime buffer, so one executable serves every
  mask/seed; see DESIGN.md §7.1).
* :func:`spmm_compressed` — weights in the compressed (values, indices)
  layout of Eq. 7 (``d_in·N/M`` values per row plus index metadata); the
  kernel expands each weight tile inside VMEM (cheap VPU gather on real
  hardware, a one-hot contraction under interpret) and feeds the MXU.
  This is the memory-saving inference layout and matches the rust
  ``sparsity::compressed`` format.

Both compute ``Y = X · (W ⊙ mask)ᵀ`` for ``X: (b, d_in)``, ``W: (d_out,
d_in)`` — Eq. 4 of the paper.  The same kernels serve BWD-2 (Eq. 6) by
passing the double-pruned mask ``mask_rc`` and swapping operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .matmul import pick_block, pick_blocks


def _spmm_masked_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, k_tiles: int):
    """Grid (m, n, k).  ``w``/``m`` tiles are (bn, bk) slices of the
    (d_out, d_in) weight; masking happens in VMEM so the HBM-resident weight
    is the *stored* operand (sparse in the compressed variant)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_sp = w_ref[...] * m_ref[...]
    acc_ref[...] += jnp.dot(x_ref[...], w_sp.T, preferred_element_type=jnp.float32)

    @pl.when(k == k_tiles - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def spmm_masked(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray, *, bm: int = 0,
                bn: int = 0, bk: int = 0) -> jnp.ndarray:
    """``Y = X · (W ⊙ mask)ᵀ`` with square-tile BlockSpecs (§2.4 tiling)."""
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2 and mask.shape == w.shape, (x.shape, w.shape, mask.shape)
    dbm, dbn, dbk = pick_blocks(m, n, k)
    bm, bn, bk = bm or dbm, bn or dbn, bk or dbk
    k_tiles = k // bk
    return pl.pallas_call(
        functools.partial(_spmm_masked_kernel, k_tiles=k_tiles),
        grid=(m // bm, n // bn, k_tiles),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w, mask)


def _spmm_compressed_kernel(x_ref, v_ref, i_ref, o_ref, *, d_in: int):
    """Grid (m, n).  Expands the compressed weight tile in VMEM then dots.

    ``v``/``i`` tiles are (bn, kc) with ``kc = d_in·N/M``; indices are
    absolute column positions.  The expansion is written as a one-hot
    contraction so it lowers to plain HLO under interpret; on TPU the same
    dataflow is a VPU scatter into VMEM scratch.
    """
    vals = v_ref[...]
    idx = i_ref[...]
    onehot = jax.nn.one_hot(idx, d_in, dtype=vals.dtype)  # (bn, kc, d_in)
    w_tile = jnp.einsum("nc,ncd->nd", vals, onehot)  # (bn, d_in) dense tile
    o_ref[...] = jnp.dot(x_ref[...], w_tile.T, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def spmm_compressed(x: jnp.ndarray, values: jnp.ndarray, indices: jnp.ndarray,
                    *, bm: int = 0, bn: int = 0) -> jnp.ndarray:
    """``Y = X · Wᵀ`` with ``W`` in the compressed N:M layout of Eq. 7.

    ``values``/``indices``: (d_out, d_in·N/M) from
    :func:`compile.sparsity.compress_nm`.
    """
    m, d_in = x.shape
    n, kc = values.shape
    assert indices.shape == values.shape
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    return pl.pallas_call(
        functools.partial(_spmm_compressed_kernel, d_in=d_in),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kc), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, kc), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, values, indices)
