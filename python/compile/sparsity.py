"""N:M sparsity mask utilities (L2, pure jnp — traceable and exportable).

Terminology follows the paper (§2.1): for a weight ``W ∈ R^{d_out × d_in}``
used as ``Y = X Wᵀ``,

* **row-wise pruning** (``W^R``) prunes along ``d_in`` — every group of M
  consecutive elements *within a row* keeps at most N non-zeros.  This is the
  reduction dimension of the forward GEMM (Eq. 4).
* **double pruning** (``W^{R,C}``) takes the already row-pruned matrix and
  prunes along ``d_out`` (the reduction dimension of BWD-2, Eq. 6) with the
  same N:M scheme, introducing the extra zeros quantified by Lemma 2.1.

Masks are float (0./1.) tensors so they can flow through the AOT-exported
HLO as ordinary buffers and be applied with element-wise multiply.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _topn_group_mask(scores: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Keep the top-``n`` scores in each group of ``m`` along the last axis.

    ``scores`` must have ``last_dim % m == 0``.  Ties are broken by position
    (earlier element wins), matching a stable top-k.
    """
    *lead, d = scores.shape
    if d % m != 0:
        raise ValueError(f"last dim {d} not divisible by group size {m}")
    g = scores.reshape(*lead, d // m, m)
    # Stable ranking: rank[i] = number of elements strictly greater, plus the
    # number of equal elements appearing earlier.
    idx = jnp.arange(m)
    gt = (g[..., None, :] > g[..., :, None]).sum(-1)
    eq_before = ((g[..., None, :] == g[..., :, None]) & (idx[None, :] < idx[:, None])).sum(-1)
    rank = gt + eq_before
    mask = (rank < n).astype(scores.dtype)
    return mask.reshape(*lead, d)


def random_nm_mask(key: jax.Array, shape, n: int, m: int, dtype=jnp.float32) -> jnp.ndarray:
    """Static random N:M mask along the last axis (SLoPe init policy §2.1).

    Every element has equal probability of being kept, satisfying the
    assumption of Lemma 2.1 / Theorem 2.2.
    """
    scores = jax.random.uniform(key, shape)
    return _topn_group_mask(scores, n, m).astype(dtype)


def magnitude_nm_mask(w: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Magnitude N:M mask along the last axis (used by SR-STE / dynamic prune)."""
    return _topn_group_mask(jnp.abs(w), n, m).astype(w.dtype)


def wanda_nm_mask(w: jnp.ndarray, act_norm: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Wanda (Sun et al. 2023) one-shot N:M mask: score = |W| · ‖X_col‖₂.

    ``act_norm`` is the per-input-feature activation L2 norm, shape
    ``(d_in,)`` for ``w`` of shape ``(d_out, d_in)``.
    """
    scores = jnp.abs(w) * act_norm[None, :]
    return _topn_group_mask(scores, n, m).astype(w.dtype)


def double_prune_mask(w: jnp.ndarray, mask_r: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Compute the ``W^{R,C}`` mask from a row-pruned weight (§2.1).

    The row-pruned weight ``w * mask_r`` is transposed and N:M pruned along
    its new last axis (= ``d_out``) by magnitude; already-zero elements
    cannot win a slot unless the whole group is zero-padded, in which case
    keeping zeros is harmless.  Returns a mask with the same layout as ``w``
    (``d_out × d_in``); the double-pruned weight is ``w * mask_rc``.
    """
    wr_t = (w * mask_r).T  # (d_in, d_out): prune along d_out
    mask_c = _topn_group_mask(jnp.abs(wr_t), n, m)
    # Intersect with the row mask: double pruning only removes, never adds.
    return (mask_c.T * mask_r).astype(w.dtype)


def imposed_sparsity(n: int, m: int) -> float:
    """Closed-form extra zeros from double pruning (Lemma 2.1, Eq. 8).

    Returns ``D(A^R) - D(A^{R,C})`` for a randomly initialized matrix: the
    expected fraction of elements newly zeroed by the column-wise pass.
    Paper values: 1:2 → 12.5%, 2:4 → 9.375%, 2:8 → 3.39%.
    """
    from math import comb

    s = n / m
    total = 0.0
    for j in range(n + 1, m + 1):
        total += comb(m, j) * s**j * (1 - s) ** (m - j) * (j - n) / m
    return total


@partial(jax.jit, static_argnames=("n", "m"))
def compress_nm(w_masked: jnp.ndarray, mask: jnp.ndarray, n: int, m: int):
    """Pack an N:M-masked matrix into the compressed (values, indices) layout.

    For ``w`` of shape ``(d_out, d_in)`` returns

    * ``values``  — ``(d_out, d_in * n / m)`` kept values, group-major;
    * ``indices`` — same shape, int32 absolute column index of each value.

    Groups with fewer than ``n`` survivors are padded with zeros pointing at
    the first masked slot (the decompress path is insensitive to the pad
    target because the padded value is 0).  Mirrors Eq. 7's index metadata
    and the rust `sparsity::compressed` format bit-for-bit in semantics.
    """
    d_out, d_in = w_masked.shape
    g = d_in // m
    wm = (w_masked * mask).reshape(d_out, g, m)
    mk = mask.reshape(d_out, g, m)
    # Order kept elements first (stable by position) using argsort on ~mask.
    order = jnp.argsort(1.0 - mk, axis=-1, stable=True)[..., :n]  # (d_out, g, n)
    vals = jnp.take_along_axis(wm, order, axis=-1)
    base = (jnp.arange(g, dtype=jnp.int32) * m)[None, :, None]
    idx = order.astype(jnp.int32) + base
    return vals.reshape(d_out, g * n), idx.reshape(d_out, g * n)


def decompress_nm(values: jnp.ndarray, indices: jnp.ndarray, d_in: int) -> jnp.ndarray:
    """Inverse of :func:`compress_nm` — scatter values back to dense."""
    d_out = values.shape[0]
    out = jnp.zeros((d_out, d_in), values.dtype)
    rows = jnp.arange(d_out)[:, None]
    return out.at[rows, indices].add(values)


# ---- Eq.-7 bit-packed metadata plane (mirrors rust sparsity::compressed) ----
#
# The rust runtime stores the index plane bit-packed: one intra-group column
# offset of ``ceil(log2 M)`` bits per kept value, LSB-first within each byte,
# every row starting byte-aligned.  These numpy helpers produce the *same*
# byte layout bit-for-bit (pinned by a golden-byte test on both sides), so
# AOT artifacts and checkpoints can ship the small metadata plane directly —
# for 2:4 that is 2 bits per kept value vs. 32 bits for an int32 index.
# Packing is an artifact-export step, so plain numpy (not traced jnp).


def offset_bits(m: int) -> int:
    """Bits per packed intra-group offset: ``ceil(log2 M)`` (0 for M=1)."""
    return int(m - 1).bit_length()


def row_meta_bytes(kc: int, m: int) -> int:
    """Packed metadata bytes per row for ``kc`` kept values (byte-aligned)."""
    return (kc * offset_bits(m) + 7) // 8


def pack_nm_offsets(indices, n: int, m: int) -> np.ndarray:
    """Bit-pack the intra-group offsets of :func:`compress_nm` indices.

    ``indices``: ``(d_out, d_in·N/M)`` int array of absolute dense columns
    (group-major, as ``compress_nm`` returns).  Returns a ``uint8`` array of
    shape ``(d_out, row_meta_bytes)`` in the rust runtime's exact layout.
    """
    idx = np.asarray(indices).astype(np.int64)
    d_out, kc = idx.shape
    bits = offset_bits(m)
    rmb = row_meta_bytes(kc, m)
    out = np.zeros((d_out, rmb), np.uint8)
    if bits == 0:
        return out
    offs = idx % m  # absolute column = group·M + offset
    if (offs < 0).any() or (offs >= m).any():
        raise ValueError("indices decode to out-of-group offsets")
    for k in range(kc):
        bitpos = k * bits
        byte, sh = bitpos >> 3, bitpos & 7
        out[:, byte] |= ((offs[:, k] << sh) & 0xFF).astype(np.uint8)
        if sh + bits > 8:  # entry straddles a byte boundary (e.g. M=8)
            out[:, byte + 1] |= ((offs[:, k] >> (8 - sh)) & 0xFF).astype(np.uint8)
    return out


def unpack_nm_offsets(packed, kc: int, n: int, m: int) -> np.ndarray:
    """Inverse of :func:`pack_nm_offsets` — absolute column indices.

    ``packed``: ``(d_out, row_meta_bytes)`` uint8; returns ``(d_out, kc)``
    int32 absolute dense column indices.
    """
    pk = np.asarray(packed).astype(np.uint16)
    d_out = pk.shape[0]
    bits = offset_bits(m)
    base = (np.arange(kc, dtype=np.int32) // n) * m
    if bits == 0:
        return np.broadcast_to(base, (d_out, kc)).copy()
    out = np.zeros((d_out, kc), np.int32)
    mask = (1 << bits) - 1
    for k in range(kc):
        bitpos = k * bits
        byte, sh = bitpos >> 3, bitpos & 7
        word = pk[:, byte] >> sh
        if sh + bits > 8:
            word = word | (pk[:, byte + 1] << (8 - sh))
        out[:, k] = (word & mask).astype(np.int32)
    return out + base[None, :]


def density(x: jnp.ndarray) -> jnp.ndarray:
    """Fraction of non-zero elements."""
    return jnp.mean((x != 0).astype(jnp.float32))
