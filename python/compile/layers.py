"""L2 building blocks: the SLoPe linear layer (Eq. 4–6 as a custom VJP)
and the transformer sub-modules that use it.

The custom VJP is the heart of the method:

* forward  (Eq. 4):  ``Y = X · (W ⊙ mask_r)ᵀ``          — row-pruned weight
* BWD-2    (Eq. 6):  ``∇X = ∇Y · (W ⊙ mask_rc)``        — double-pruned
* BWD-1    (Eq. 5):  ``∇W = (∇Yᵀ · X) ⊙ mask_r``        — masked gradient
  (Algorithm 1 line 13: never materialize updates for pruned slots)

All three GEMMs go through the L1 Pallas kernels so the AOT-exported HLO
contains the same tiled dataflow the rust runtime executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import matmul, matmul_add, spmm_masked
from .kernels.prune_compress import apply_mask


# ---------------------------------------------------------------------------
# SLoPe sparse linear
# ---------------------------------------------------------------------------

@jax.custom_vjp
def slope_matmul(x, w, mask_r, mask_rc):
    """``Y = X·(W⊙mask_r)ᵀ`` with the double-pruned backward pass.

    ``x``: (tokens, d_in); ``w``: (d_out, d_in); masks shaped like ``w``.
    """
    return spmm_masked(x, w, mask_r)


def _slope_matmul_fwd(x, w, mask_r, mask_rc):
    return spmm_masked(x, w, mask_r), (x, w, mask_r, mask_rc)


def _slope_matmul_bwd(res, gy):
    x, w, mask_r, mask_rc = res
    # BWD-2 (Eq. 6): ∇X = ∇Y · W^{R,C} — N:M sparse along d_out, so this GEMM
    # also runs on sparse hardware.  spmm_masked computes A·(B⊙m)ᵀ, so feed
    # the transposed weight/mask.
    gx = spmm_masked(gy, w.T, mask_rc.T)
    # BWD-1 (Eq. 5) + Algorithm 1 line 13: dense GEMM, then prune to the
    # static support so the optimizer state stays sparse.
    gw = apply_mask(matmul(gy.T, x), mask_r)
    return gx, gw, jnp.zeros_like(mask_r), jnp.zeros_like(mask_rc)


slope_matmul.defvjp(_slope_matmul_fwd, _slope_matmul_bwd)


def slope_linear(x, w, b, mask_r, mask_rc):
    """Sparse linear with bias over a (..., d_in) input."""
    lead = x.shape[:-1]
    y = slope_matmul(x.reshape(-1, x.shape[-1]), w, mask_r, mask_rc)
    return y.reshape(*lead, -1) + b


def slope_linear_lora(x, w, b, mask_r, mask_rc, lo_down, lo_up):
    """Sparse linear + low-rank adapter: ``Y = X·W_spᵀ + (X·Rᵀ)·Lᵀ + b``.

    ``lo_down`` = R: (r, d_in); ``lo_up`` = L: (d_out, r).  The adapter path
    uses the L1 fused matmul+add (Eq. 11-right) so ``Y2·L + Y1`` is one
    kernel.  Gradients flow to both the sparse weight (via the SLoPe custom
    VJP) and the adapter factors (plain autodiff).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y1 = slope_matmul(x2, w, mask_r, mask_rc)
    t = matmul(x2, lo_down.T)
    y = matmul_add(t, lo_up.T, y1)
    return y.reshape(*lead, -1) + b


def dense_linear(x, w, b):
    """Dense linear through the same L1 matmul kernel (used for the LM head
    and anywhere pruning is disabled)."""
    lead = x.shape[:-1]
    y = matmul(x.reshape(-1, x.shape[-1]), w.T)
    return y.reshape(*lead, -1) + b


# ---------------------------------------------------------------------------
# Pruning variants for the Figure-9 ablation (choice of pruned matrix)
# ---------------------------------------------------------------------------

def ste_masked(v, mask):
    """Straight-through masked value: forward sees ``v⊙mask``, gradient
    flows to dense ``v`` (the mechanism dynamic-mask methods rely on)."""
    return v + jax.lax.stop_gradient(v * mask - v)


def variant_linear(x, w, b, variant, mask_w, mask_x, n: int, m: int):
    """Linear layer under one of the Fig. 9 pruning policies.

    ``variant`` ∈ {``weight_static``, ``weight_dynamic``, ``input_static``,
    ``input_dynamic``, ``gradout_dynamic``, ``dense``}.  Dynamic variants
    recompute a magnitude N:M mask every call (the paper stores dense values
    and prunes on the fly); static variants use the fixed masks handed in.
    ``gradout_dynamic`` prunes the *output gradient* — the configuration the
    paper reports as divergent.
    """
    from .sparsity import magnitude_nm_mask

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if variant == "dense":
        pass
    elif variant == "weight_static":
        w = w * mask_w
    elif variant == "weight_dynamic":
        w = ste_masked(w, magnitude_nm_mask(w, n, m))
    elif variant == "input_static":
        x2 = x2 * mask_x[None, :]
    elif variant == "input_dynamic":
        x2 = ste_masked(x2, magnitude_nm_mask(x2, n, m))
    elif variant == "gradout_dynamic":
        x2 = _prune_gradout(x2, n, m)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    y = matmul(x2, w.T)
    return y.reshape(*lead, -1) + b


@jax.custom_vjp
def _prune_gradout(x, n: int, m: int):
    return x


def _pg_fwd(x, n, m):
    return x, (n, m)


def _pg_bwd(res, gy):
    from .sparsity import magnitude_nm_mask

    n, m = res
    return (gy * magnitude_nm_mask(gy, n, m), None, None)


_prune_gradout.defvjp(_pg_fwd, _pg_bwd)


# ---------------------------------------------------------------------------
# Transformer sub-modules
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps: float = 1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def causal_attention(q, k, v, n_head: int):
    """Standard causal multi-head attention (B, S, d) → (B, S, d)."""
    b, s, d = q.shape
    hd = d // n_head

    def split(t):
        return t.reshape(b, s, n_head, hd).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd).astype(q.dtype)
    causal = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(causal[None, None], att, jnp.finfo(att.dtype).min)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)
