"""AOT exporter: lower every executable to HLO *text* + a JSON manifest.

``make artifacts`` runs this once; the rust runtime then loads
``artifacts/<config>/<name>.hlo.txt`` via ``HloModuleProto::from_text_file``
and never touches python again.

HLO text (NOT ``lowered.compiler_ir("hlo")`` protos or ``.serialize()``) is
the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The manifest records, for every executable, the flattened input/output
order (dotted path names, shapes, dtypes) so the rust parameter store can
marshal literals positionally and feed step outputs back into step inputs
by name.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import (MODEL_CONFIGS, ModelConfig, TrainConfig,
                      get_model_config, get_train_config)
from . import model as M
from . import sparsity as sp
from . import train as T


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_to_name(prefix: str, path) -> str:
    parts = [prefix]
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _spec_tree(prefix: str, tree) -> List[Dict]:
    """Flatten a pytree into [{name, shape, dtype}] in jax flatten order."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        out.append({
            "name": _path_to_name(prefix, path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        })
    return out


def export_fn(fn: Callable, arg_specs: Sequence[Tuple[str, object]], out_prefixes,
              out_dir: str, name: str) -> Dict:
    """Lower ``fn(*args)`` with abstract args, write HLO text, return the
    manifest entry.  ``arg_specs``: [(prefix, pytree_of_ShapeDtypeStruct)].
    ``out_prefixes``: names for the result pytree elements (tuple results)."""
    args = [spec for _, spec in arg_specs]
    # keep_unused=True: the manifest promises EVERY declared arg is a real
    # HLO parameter; without it jit DCEs unused inputs (e.g. magnitude_masks
    # reads only block weights) and the rust marshalling contract breaks.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    inputs: List[Dict] = []
    for prefix, spec in arg_specs:
        inputs.extend(_spec_tree(prefix, spec))

    # Recover the output structure by abstract evaluation.
    out_shape = jax.eval_shape(fn, *args)
    if not isinstance(out_shape, tuple):
        out_shape = (out_shape,)
        out_prefixes = [out_prefixes] if isinstance(out_prefixes, str) else out_prefixes
    outputs: List[Dict] = []
    for prefix, spec in zip(out_prefixes, out_shape):
        outputs.extend(_spec_tree(prefix, spec))

    print(f"  wrote {fname}: {len(text)} chars, {len(inputs)} in / {len(outputs)} out")
    return {"file": fname, "inputs": inputs, "outputs": outputs}


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# Per-config export sets
# ---------------------------------------------------------------------------

def export_config(cfg: ModelConfig, tc: TrainConfig, out_root: str,
                  sets: Sequence[str]) -> Dict:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    print(f"[aot] exporting {cfg.name} (~{cfg.n_params()/1e6:.1f}M params): {','.join(sets)}")

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    masks = M.init_masks(cfg, params, key)
    opt = T.init_opt_state(params)
    lora = M.init_lora(cfg, key)
    lora_opt = T.init_opt_state(lora)

    a_params, a_masks = _abstract(params), _abstract(masks)
    a_opt, a_lora, a_lora_opt = _abstract(opt), _abstract(lora), _abstract(lora_opt)
    tok_train = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len + 1), jnp.int32)
    tok_infer = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len), jnp.int32)
    seed = jax.ShapeDtypeStruct((), jnp.int32)

    exes: Dict[str, Dict] = {}

    if "core" in sets:
        def init_fn(s):
            k = jax.random.PRNGKey(s)
            k1, k2 = jax.random.split(k)
            p = M.init_params(cfg, k1)
            masks = M.init_masks(cfg, p, k2)
            # Project weights onto the static support: SLoPe stores sparse
            # weights from step 0 (Algorithm 1 lines 3-4).
            p = M.project_params(cfg, p, masks)
            return p, T.init_opt_state(p), masks

        exes["init"] = export_fn(
            init_fn, [("seed", seed)], ["params", "opt", "masks"], out_dir, "init")

        step = T.make_train_step(cfg, tc)
        exes["train_step"] = export_fn(
            step,
            [("tokens", tok_train), ("params", a_params), ("opt", a_opt),
             ("masks", a_masks)],
            ["loss", "params", "opt"], out_dir, "train_step")

        def lora_init_fn(s):
            lo = M.init_lora(cfg, jax.random.PRNGKey(s))
            return lo, T.init_opt_state(lo)

        exes["lora_init"] = export_fn(
            lora_init_fn, [("seed", seed)], ["lora", "lora_opt"], out_dir, "lora_init")

        step_lora = T.make_train_step_lora(cfg, tc)
        exes["train_step_lora"] = export_fn(
            step_lora,
            [("tokens", tok_train), ("params", a_params), ("opt", a_opt),
             ("masks", a_masks), ("lora", a_lora), ("lora_opt", a_lora_opt)],
            ["loss", "params", "opt", "lora", "lora_opt"], out_dir, "train_step_lora")

        exes["eval_step"] = export_fn(
            T.make_eval_step(cfg),
            [("tokens", tok_train), ("params", a_params), ("masks", a_masks)],
            ["loss"], out_dir, "eval_step")

        exes["eval_step_lora"] = export_fn(
            T.make_eval_step(cfg, with_lora=True),
            [("tokens", tok_train), ("params", a_params), ("masks", a_masks),
             ("lora", a_lora)],
            ["loss"], out_dir, "eval_step_lora")

        exes["forward"] = export_fn(
            T.make_forward(cfg),
            [("tokens", tok_infer), ("params", a_params), ("masks", a_masks)],
            ["logits"], out_dir, "forward")

        exes["forward_lora"] = export_fn(
            T.make_forward(cfg, with_lora=True),
            [("tokens", tok_infer), ("params", a_params), ("masks", a_masks),
             ("lora", a_lora)],
            ["logits"], out_dir, "forward_lora")

    if "srste" in sets:
        step_srste = T.make_train_step_srste(cfg, tc)
        exes["train_step_srste"] = export_fn(
            step_srste,
            [("tokens", tok_train), ("params", a_params), ("opt", a_opt)],
            ["loss", "params", "opt"], out_dir, "train_step_srste")

        exes["srste_masks"] = export_fn(
            lambda p: T.srste_mask_snapshot(cfg, p),
            [("params", a_params)], ["masks"], out_dir, "srste_masks")

        # Re-mask a trained model by magnitude (also used to hand an SR-STE
        # result to the sparse eval path).
        exes["magnitude_masks"] = export_fn(
            lambda p: M.init_masks(cfg, p, jax.random.PRNGKey(0), scheme="magnitude"),
            [("params", a_params)], ["masks"], out_dir, "magnitude_masks")

    if "wanda" in sets:
        exes["wanda_masks"] = export_fn(
            lambda p, t: M.wanda_masks(cfg, p, t),
            [("params", a_params), ("tokens", tok_infer)],
            ["masks"], out_dir, "wanda_masks")

    if "fig9" in sets:
        fig9_masks = T.make_fig9_masks(cfg, key)
        a_f9 = _abstract(fig9_masks)
        exes["fig9_init"] = export_fn(
            lambda s: T.make_fig9_masks(cfg, jax.random.PRNGKey(s)),
            [("seed", seed)], ["fig9_masks"], out_dir, "fig9_init")
        for variant in T.FIG9_VARIANTS:
            if variant == "dense":
                continue  # dense == core train_step with ones masks
            step_v = T.make_train_step_fig9(cfg, tc, variant)
            exes[f"train_step_fig9_{variant}"] = export_fn(
                step_v,
                [("tokens", tok_train), ("params", a_params), ("opt", a_opt),
                 ("masks", a_masks), ("fig9_masks", a_f9)],
                ["loss", "params", "opt"], out_dir, f"train_step_fig9_{variant}")

    manifest = {
        "config": {
            "name": cfg.name, "vocab_size": cfg.vocab_size,
            "n_layer": cfg.n_layer, "n_head": cfg.n_head,
            "d_model": cfg.d_model, "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len, "max_seq": cfg.pos_len, "batch_size": cfg.batch_size,
            "adapter_rank": cfg.adapter_rank,
            "first_half_sparsity": [cfg.first_half_sparsity.n, cfg.first_half_sparsity.m],
            "second_half_sparsity": [cfg.second_half_sparsity.n, cfg.second_half_sparsity.m],
            "prune_attn": cfg.prune_attn, "prune_mlp": cfg.prune_mlp,
            "n_params_dense": cfg.n_params(),
        },
        # The Eq.-7 bit-packed index layout shipped alongside compressed
        # weights (mirrors rust sparsity::compressed bit-for-bit; see
        # sparsity.pack_nm_offsets): one intra-group offset of
        # ceil(log2 M) bits per kept value, LSB-first, rows byte-aligned.
        "sparsity_format": {
            "layout": "eq7-packed-offsets-v1",
            "row_byte_aligned": True,
            "offset_bits_first_half": sp.offset_bits(cfg.first_half_sparsity.m),
            "offset_bits_second_half": sp.offset_bits(cfg.second_half_sparsity.m),
        },
        "train": {
            "lr": tc.lr, "beta1": tc.beta1, "beta2": tc.beta2,
            "weight_decay": tc.weight_decay, "grad_clip": tc.grad_clip,
            "warmup_steps": tc.warmup_steps, "total_steps": tc.total_steps,
            "lazy_fraction": tc.lazy_fraction, "srste_decay": tc.srste_decay,
        },
        "executables": exes,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


# Which export sets each config receives (see DESIGN.md §5).
EXPORT_PLAN: Dict[str, Tuple[str, Sequence[str]]] = {
    "gpt-nano": ("default", ("core", "srste", "wanda", "fig9")),
    "gpt-micro": ("default", ("core", "srste")),
    "gpt-mini": ("e2e", ("core",)),
    "bert-phase1": ("short", ("core",)),
    "bert-phase2": ("short", ("core",)),
    "gpt-nano-24-28": ("default", ("core", "wanda")),
    "gpt-nano-28-24": ("default", ("core", "wanda")),
    "gpt-nano-mlponly": ("default", ("core",)),
    "gpt-nano-half-depth": ("default", ("core",)),
    "gpt-nano-half-width": ("default", ("core",)),
    "gpt-nano-r2": ("default", ("core",)),
    "bert-phase2-r2": ("short", ("core",)),
    "bert-phase2-r32": ("short", ("core",)),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root dir")
    ap.add_argument("--configs", default="all",
                    help="comma-separated config names, or 'all'")
    args = ap.parse_args()

    names = list(EXPORT_PLAN) if args.configs == "all" else args.configs.split(",")
    os.makedirs(args.out, exist_ok=True)
    index = {}
    for name in names:
        tc_name, sets = EXPORT_PLAN[name]
        cfg = get_model_config(name)
        tc = get_train_config(tc_name)
        export_config(cfg, tc, args.out, sets)
        index[name] = {"dir": name, "train_config": tc_name, "sets": list(sets)}
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"[aot] index written for {len(index)} configs")


if __name__ == "__main__":
    main()
