"""SLoPe build-time package: L1 Pallas kernels + L2 JAX model + AOT export.

Python is build-time only — ``make artifacts`` runs ``compile.aot`` once and
the rust coordinator consumes ``artifacts/*.hlo.txt`` thereafter.
"""
