"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-square, multi-tile grids and odd
group counts) and dtypes; assert_allclose against ref.py is THE correctness
signal for the kernels the AOT pipeline bakes into the HLO artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import sparsity as sp
from compile.kernels import (apply_mask, lora_forward_fused, lora_forward_naive,
                             matmul, matmul_add, matmul_add_blocked,
                             matmul_blocked, prune_and_compress, sparse_add,
                             spmm_compressed, spmm_masked)
from compile.kernels import ref

TOL = dict(rtol=1e-4, atol=1e-5)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul family
# ---------------------------------------------------------------------------

dims = st.sampled_from([4, 8, 12, 16, 24, 32, 64])


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**30))
def test_matmul_blocked_matches_ref(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x, w = _rand(kx, m, k), _rand(kw, k, n)
    np.testing.assert_allclose(matmul_blocked(x, w), ref.matmul_ref(x, w), **TOL)


@given(m=dims, k=dims, n=dims, bm=st.sampled_from([2, 4]), bk=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**30))
def test_matmul_multi_tile_grids(m, k, n, bm, bk, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x, w = _rand(kx, m, k), _rand(kw, k, n)
    out = matmul_blocked(x, w, bm=bm, bk=bk, bn=min(n, 4))
    np.testing.assert_allclose(out, ref.matmul_ref(x, w), **TOL)


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**30))
def test_matmul_add_fused(m, k, n, seed):
    kx, kw, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w, c = _rand(kx, m, k), _rand(kw, k, n), _rand(kc, m, n)
    np.testing.assert_allclose(matmul_add_blocked(x, w, c),
                               ref.matmul_add_ref(x, w, c), **TOL)


def test_matmul_grad_is_pallas_and_correct():
    x = _rand(jax.random.PRNGKey(0), 8, 16)
    w = _rand(jax.random.PRNGKey(1), 16, 12)
    gx, gw = jax.grad(lambda a, b: matmul(a, b).sum(), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, jnp.ones((8, 12)) @ w.T, **TOL)
    np.testing.assert_allclose(gw, x.T @ jnp.ones((8, 12)), **TOL)


def test_matmul_add_grads():
    x = _rand(jax.random.PRNGKey(0), 4, 8)
    w = _rand(jax.random.PRNGKey(1), 8, 6)
    c = _rand(jax.random.PRNGKey(2), 4, 6)
    gx, gw, gc = jax.grad(lambda a, b, cc: matmul_add(a, b, cc).sum(),
                          argnums=(0, 1, 2))(x, w, c)
    np.testing.assert_allclose(gc, jnp.ones((4, 6)), **TOL)
    np.testing.assert_allclose(gx, jnp.ones((4, 6)) @ w.T, **TOL)


# ---------------------------------------------------------------------------
# N:M SpMM
# ---------------------------------------------------------------------------

nm = st.sampled_from([(1, 2), (2, 4), (2, 8), (4, 8)])


@given(b=dims, nm=nm, groups=st.sampled_from([2, 3, 4, 8]),
       dout=dims, seed=st.integers(0, 2**30))
def test_spmm_masked_matches_ref(b, nm, groups, dout, seed):
    n, m = nm
    din = groups * m
    kx, kw, km = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w = _rand(kx, b, din), _rand(kw, dout, din)
    mask = sp.random_nm_mask(km, w.shape, n, m)
    np.testing.assert_allclose(spmm_masked(x, w, mask),
                               ref.spmm_masked_ref(x, w, mask), **TOL)


@given(b=dims, nm=nm, groups=st.sampled_from([2, 4, 8]), dout=dims,
       seed=st.integers(0, 2**30))
def test_spmm_compressed_matches_masked(b, nm, groups, dout, seed):
    """Compressed layout (Eq. 7) must be bit-equivalent to masked-dense."""
    n, m = nm
    din = groups * m
    kx, kw, km = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w = _rand(kx, b, din), _rand(kw, dout, din)
    mask = sp.random_nm_mask(km, w.shape, n, m)
    vals, idx = sp.compress_nm(w * mask, mask, n, m)
    np.testing.assert_allclose(spmm_compressed(x, vals, idx),
                               ref.spmm_masked_ref(x, w, mask), **TOL)
    np.testing.assert_allclose(
        ref.spmm_compressed_ref(x, vals, idx, din),
        ref.spmm_masked_ref(x, w, mask), **TOL)


def test_spmm_masked_tile_invariance():
    """Tiling must not change the result (§2.4 square-tile optimization)."""
    x = _rand(jax.random.PRNGKey(0), 16, 32)
    w = _rand(jax.random.PRNGKey(1), 64, 32)
    mask = sp.random_nm_mask(jax.random.PRNGKey(2), w.shape, 2, 4)
    base = ref.spmm_masked_ref(x, w, mask)
    for bm, bn, bk in [(16, 64, 32), (8, 8, 8), (4, 16, 16), (16, 32, 4)]:
        np.testing.assert_allclose(spmm_masked(x, w, mask, bm=bm, bn=bn, bk=bk),
                                   base, **TOL)


# ---------------------------------------------------------------------------
# LoRA fusion (Eq. 11)
# ---------------------------------------------------------------------------

@given(b=dims, dout=dims, groups=st.sampled_from([2, 4]),
       r=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 2**30))
def test_lora_naive_and_fused_match_ref(b, dout, groups, r, seed):
    din = groups * 4
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    x, w = _rand(keys[0], b, din), _rand(keys[1], dout, din)
    mask = sp.random_nm_mask(keys[2], w.shape, 2, 4)
    lo_l, lo_r = _rand(keys[3], dout, r), _rand(keys[4], r, din)
    want = ref.lora_ref(x, w, mask, lo_l, lo_r)
    np.testing.assert_allclose(lora_forward_naive(x, w, mask, lo_l, lo_r), want, **TOL)
    np.testing.assert_allclose(lora_forward_fused(x, w, mask, lo_l, lo_r), want, **TOL)


def test_lora_fused_equals_naive_large():
    keys = jax.random.split(jax.random.PRNGKey(7), 5)
    x = _rand(keys[0], 32, 128)
    w = _rand(keys[1], 256, 128)
    mask = sp.random_nm_mask(keys[2], w.shape, 2, 4)
    lo_l, lo_r = _rand(keys[3], 256, 16), _rand(keys[4], 16, 128)
    np.testing.assert_allclose(lora_forward_fused(x, w, mask, lo_l, lo_r),
                               lora_forward_naive(x, w, mask, lo_l, lo_r),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# prune&compress / sparse add (Algorithm 1 helpers)
# ---------------------------------------------------------------------------

@given(dout=dims, groups=st.sampled_from([2, 4, 8]), nm=nm,
       seed=st.integers(0, 2**30))
def test_prune_and_compress(dout, groups, nm, seed):
    n, m = nm
    din = groups * m
    kg, kw, km = jax.random.split(jax.random.PRNGKey(seed), 3)
    g, w = _rand(kg, dout, din), _rand(kw, dout, din)
    mask = sp.random_nm_mask(km, w.shape, n, m)
    _, idx = sp.compress_nm(w * mask, mask, n, m)
    np.testing.assert_allclose(prune_and_compress(g, idx),
                               ref.prune_and_compress_ref(g, idx))


@given(rows=dims, cols=dims, beta=st.floats(-2, 2), gamma=st.floats(-2, 2),
       seed=st.integers(0, 2**30))
def test_sparse_add(rows, cols, beta, gamma, seed):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(ka, rows, cols), _rand(kb, rows, cols)
    np.testing.assert_allclose(sparse_add(a, b, beta, gamma),
                               ref.sparse_add_ref(a, b, beta, gamma),
                               rtol=1e-4, atol=1e-4)


def test_apply_mask():
    g = _rand(jax.random.PRNGKey(0), 16, 32)
    mask = sp.random_nm_mask(jax.random.PRNGKey(1), g.shape, 2, 4)
    np.testing.assert_allclose(apply_mask(g, mask), g * mask)


# ---------------------------------------------------------------------------
# The full SLoPe linear contract (Eq. 4–6) through the custom VJP
# ---------------------------------------------------------------------------

def test_slope_matmul_eq456():
    from compile.layers import slope_matmul

    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    x = _rand(keys[0], 8, 16)
    w = _rand(keys[1], 12, 16)
    mask_r = sp.random_nm_mask(keys[2], w.shape, 2, 4)
    mask_rc = sp.double_prune_mask(w, mask_r, 2, 4)
    gy = _rand(jax.random.PRNGKey(4), 8, 12)

    y, vjp = jax.vjp(lambda xx, ww: slope_matmul(xx, ww, mask_r, mask_rc), x, w)
    gx, gw = vjp(gy)
    want_y, want_gx, want_gw = ref.slope_linear_ref(x, w, mask_r, mask_rc, gy)
    np.testing.assert_allclose(y, want_y, **TOL)
    np.testing.assert_allclose(gx, want_gx, **TOL)
    np.testing.assert_allclose(gw, want_gw, **TOL)
    # Invariant: grad-W support never exceeds the static row mask.
    assert float(jnp.abs(gw * (1 - mask_r)).max()) == 0.0


def test_double_prune_uses_fewer_nonzeros_than_row_prune():
    """gx through mask_rc must differ from gx through mask_r exactly on the
    double-pruned (red, Figure 1) positions."""
    from compile.layers import slope_matmul

    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    x = _rand(keys[0], 4, 32)
    w = _rand(keys[1], 16, 32)
    mask_r = sp.random_nm_mask(keys[2], w.shape, 2, 4)
    mask_rc = sp.double_prune_mask(w, mask_r, 2, 4)
    assert float(mask_rc.sum()) < float(mask_r.sum())
    gy = _rand(jax.random.PRNGKey(6), 4, 16)
    _, vjp = jax.vjp(lambda xx: slope_matmul(xx, w, mask_r, mask_rc), x)
    (gx,) = vjp(gy)
    np.testing.assert_allclose(gx, gy @ (w * mask_rc), **TOL)
