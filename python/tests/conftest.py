"""Shared pytest fixtures/settings for the SLoPe build-time test suite."""

import jax
import pytest
from hypothesis import settings

# Pallas interpret mode is slow; keep hypothesis example counts sane.
settings.register_profile("slope", max_examples=12, deadline=None)
settings.load_profile("slope")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
