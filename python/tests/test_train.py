"""Train-step semantics: Algorithm-1 invariants, optimizer behaviour,
baseline steps (SR-STE, Fig-9 variants), schedule shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.configs import ModelConfig, TrainConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="t", vocab_size=64, n_layer=2, n_head=2, d_model=32,
                      d_ff=64, seq_len=32, batch_size=4, adapter_rank=4)
    tc = TrainConfig(total_steps=100, warmup_steps=5)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    masks = M.init_masks(cfg, params, key)
    opt = T.init_opt_state(params)
    tok = jax.random.randint(key, (cfg.batch_size, cfg.seq_len + 1), 0, cfg.vocab_size)
    return cfg, tc, params, masks, opt, tok


def _support_violation(params, masks):
    worst = 0.0
    for i, bm in masks["blocks"].items():
        for wname in M.SPARSE_WEIGHTS:
            w = params["blocks"][i][wname]
            off = jnp.abs(w * (1 - bm[wname + "_r"])).max()
            worst = max(worst, float(off))
    return worst


def test_train_step_decreases_loss_and_keeps_support(tiny):
    cfg, tc, params, masks, opt, tok = tiny
    # Project initial weights onto the mask support (the coordinator does
    # this implicitly because init happens before masking in the paper; we
    # enforce it so the invariant is exact from step 0).
    step = jax.jit(T.make_train_step(cfg, tc))
    losses = []
    p, o = params, opt
    for _ in range(5):
        loss, p, o = step(tok, p, o, masks)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    # Pruned slots must never receive updates (Algorithm 1 lines 17–18).
    v0 = _support_violation(params, masks)
    v1 = _support_violation(p, masks)
    assert v1 <= v0 + 1e-7


def test_opt_state_stays_masked(tiny):
    cfg, tc, params, masks, opt, tok = tiny
    step = jax.jit(T.make_train_step(cfg, tc))
    _, p, o = step(tok, params, opt, masks)
    for i, bm in masks["blocks"].items():
        for wname in M.SPARSE_WEIGHTS:
            m = o["m"]["blocks"][i][wname]
            off = float(jnp.abs(m * (1 - bm[wname + "_r"])).max())
            assert off == 0.0, f"optimizer moment leaked outside mask: {wname}"


def test_step_counter_increments(tiny):
    cfg, tc, params, masks, opt, tok = tiny
    step = jax.jit(T.make_train_step(cfg, tc))
    _, _, o1 = step(tok, params, opt, masks)
    _, _, o2 = step(tok, params, o1, masks)
    assert float(o1["step"]) == 1.0 and float(o2["step"]) == 2.0


def test_lora_step_trains_adapters(tiny):
    cfg, tc, params, masks, opt, tok = tiny
    lora = M.init_lora(cfg, jax.random.PRNGKey(1))
    lopt = T.init_opt_state(lora)
    step = jax.jit(T.make_train_step_lora(cfg, tc))
    loss0, p, o, lo, lopt = step(tok, params, opt, masks, lora, lopt)
    loss1, p, o, lo, lopt = step(tok, p, o, masks, lo, lopt)
    assert float(loss1) < float(loss0)
    # Upsample factors must move off their zero init.
    up = lo["blocks"]["0"]["wup_up"]
    assert float(jnp.abs(up).max()) > 0.0


def test_lr_schedule_shape():
    tc = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(T.lr_schedule(tc, jnp.array(float(s)))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6  # peak at end of warmup
    assert lrs[-1] < 0.2  # decayed
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # monotone decay


def test_srste_step_runs_and_stays_dense(tiny):
    cfg, tc, params, masks, opt, tok = tiny
    step = jax.jit(T.make_train_step_srste(cfg, tc))
    loss0, p, o = step(tok, params, opt)
    loss1, p, o = step(tok, p, o)
    assert float(loss1) < float(loss0)
    # SR-STE keeps dense weights: no exact-zero support pattern.
    w = p["blocks"]["1"]["wup"]
    assert float((w == 0).mean()) < 0.01


def test_srste_mask_snapshot_shapes(tiny):
    cfg, tc, params, *_ = tiny
    snap = T.srste_mask_snapshot(cfg, params)
    m = snap["blocks"]["1"]["wup"]
    g = np.asarray(m).reshape(m.shape[0], -1, 4)
    assert (g.sum(-1) == 2).all()


@pytest.mark.parametrize("variant", ["weight_static", "weight_dynamic",
                                     "input_static", "input_dynamic"])
def test_fig9_variants_train(tiny, variant):
    cfg, tc, params, masks, opt, tok = tiny
    f9 = T.make_fig9_masks(cfg, jax.random.PRNGKey(2))
    step = jax.jit(T.make_train_step_fig9(cfg, tc, variant))
    loss0, p, o = step(tok, params, opt, masks, f9)
    loss1, _, _ = step(tok, p, o, masks, f9)
    assert np.isfinite(float(loss0)) and float(loss1) < float(loss0)


def test_fig9_gradout_variant_runs(tiny):
    """The gradient-output-pruned variant must run (the paper reports it
    *diverges over training* — that long-horizon behaviour is exercised by
    the rust fig9 harness, not this unit test)."""
    cfg, tc, params, masks, opt, tok = tiny
    f9 = T.make_fig9_masks(cfg, jax.random.PRNGKey(2))
    step = jax.jit(T.make_train_step_fig9(cfg, tc, "gradout_dynamic"))
    loss0, p, o = step(tok, params, opt, masks, f9)
    assert np.isfinite(float(loss0))


def test_update_masks_structure(tiny):
    cfg, tc, params, masks, *_ = tiny
    um = T.update_masks_from(masks, params)
    assert um["tok_emb"] is None
    assert um["blocks"]["0"]["ln1_g"] is None
    assert um["blocks"]["1"]["wup"] is not None
