"""AOT manifest contract tests (run after `make artifacts`).

These validate the python→rust interface without touching XLA: flattened
name/shape/dtype order, state round-trip compatibility between executables,
and export-plan coverage.
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "index.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def load(config):
    with open(os.path.join(ART, config, "manifest.json")) as f:
        return json.load(f)


def test_index_covers_all_export_plan_configs():
    from compile.aot import EXPORT_PLAN

    with open(os.path.join(ART, "index.json")) as f:
        index = json.load(f)
    assert set(index) == set(EXPORT_PLAN)


def test_train_step_state_roundtrip():
    m = load("gpt-nano")
    ts = m["executables"]["train_step"]
    ins = {t["name"]: (t["shape"], t["dtype"]) for t in ts["inputs"]}
    for out in ts["outputs"]:
        if out["name"].startswith(("params.", "opt.")):
            assert out["name"] in ins, f"output {out['name']} has no matching input"
            assert ins[out["name"]] == (out["shape"], out["dtype"])


def test_init_provides_everything_train_step_needs():
    m = load("gpt-nano")
    init_outs = {t["name"] for t in m["executables"]["init"]["outputs"]}
    for t in m["executables"]["train_step"]["inputs"]:
        if t["name"] != "tokens":
            assert t["name"] in init_outs, f"train_step input {t['name']} not initialized"


def test_lora_state_roundtrip_through_lora_step():
    m = load("gpt-nano")
    li = {t["name"] for t in m["executables"]["lora_init"]["outputs"]}
    tsl = m["executables"]["train_step_lora"]
    lora_ins = {t["name"] for t in tsl["inputs"] if t["name"].startswith("lora")}
    assert lora_ins == li


def test_tokens_shapes():
    m = load("gpt-nano")
    c = m["config"]
    ts_tok = next(t for t in m["executables"]["train_step"]["inputs"] if t["name"] == "tokens")
    assert ts_tok["shape"] == [c["batch_size"], c["seq_len"] + 1]
    assert ts_tok["dtype"] == "int32"
    fwd_tok = next(t for t in m["executables"]["forward"]["inputs"] if t["name"] == "tokens")
    assert fwd_tok["shape"] == [c["batch_size"], c["seq_len"]]


def test_hlo_files_exist_and_are_text():
    m = load("gpt-nano")
    for name, exe in m["executables"].items():
        path = os.path.join(ART, "gpt-nano", exe["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_rank_variant_configs_differ_only_in_adapter_rank():
    base = load("bert-phase2")["config"]
    r2 = load("bert-phase2-r2")["config"]
    r32 = load("bert-phase2-r32")["config"]
    for k in base:
        if k in ("name", "adapter_rank"):
            continue
        assert base[k] == r2[k] == r32[k], k
    assert (r2["adapter_rank"], base["adapter_rank"], r32["adapter_rank"]) == (2, 8, 32)


def test_phase_transfer_param_shapes_match():
    """bert-phase1 → bert-phase2 checkpoint transfer requires identical
    params.* shapes (pos_emb sized to max_seq)."""
    p1 = {t["name"]: t["shape"]
          for t in load("bert-phase1")["executables"]["train_step"]["inputs"]
          if t["name"].startswith("params.")}
    p2 = {t["name"]: t["shape"]
          for t in load("bert-phase2")["executables"]["train_step"]["inputs"]
          if t["name"].startswith("params.")}
    assert p1 == p2


def test_srste_step_has_no_mask_inputs():
    m = load("gpt-nano")
    srste = m["executables"]["train_step_srste"]
    assert not any(t["name"].startswith("masks.") for t in srste["inputs"])
