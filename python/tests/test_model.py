"""L2 model correctness: shapes, sparsity policy, adapter no-op init,
mask plumbing, Wanda calibration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import sparsity as sp
from compile.configs import ModelConfig, SparsityConfig, get_model_config


@pytest.fixture(scope="module")
def nano():
    cfg = get_model_config("gpt-nano")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    masks = M.init_masks(cfg, params, key)
    return cfg, params, masks


def test_param_count_close_to_formula(nano):
    cfg, params, _ = nano
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert abs(n - cfg.n_params()) / cfg.n_params() < 0.05


def test_forward_shapes_and_finiteness(nano):
    cfg, params, masks = nano
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq_len), 0, cfg.vocab_size)
    logits = M.forward(cfg, params, masks, tok)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_causality(nano):
    """Changing a future token must not affect past logits."""
    cfg, params, masks = nano
    tok = jax.random.randint(jax.random.PRNGKey(2), (1, cfg.seq_len), 0, cfg.vocab_size)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 1) % cfg.vocab_size)
    l1 = M.forward(cfg, params, masks, tok)
    l2 = M.forward(cfg, params, masks, tok2)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-4, atol=1e-5)
    assert float(jnp.abs(l1[0, -1] - l2[0, -1]).max()) > 1e-6


def test_mask_policy_first_layer_qkv_dense(nano):
    """Paper §3.2: first linear after the input is dense; everything else 2:4."""
    cfg, _, masks = nano
    b0 = masks["blocks"]["0"]
    assert float(b0["wqkv_r"].mean()) == 1.0
    assert abs(float(b0["wproj_r"].mean()) - 0.5) < 1e-6
    for i in range(1, cfg.n_layer):
        bm = masks["blocks"][str(i)]
        for wname in M.SPARSE_WEIGHTS:
            assert abs(float(bm[wname + "_r"].mean()) - 0.5) < 1e-6
            assert float(bm[wname + "_rc"].mean()) <= float(bm[wname + "_r"].mean())


def test_mixed_sparsity_config():
    cfg = get_model_config("gpt-nano-24-28")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    masks = M.init_masks(cfg, params, key)
    # First half 2:4 (density .5), second half 2:8 (density .25).
    assert abs(float(masks["blocks"]["1"]["wup_r"].mean()) - 0.5) < 1e-6
    last = str(cfg.n_layer - 1)
    assert abs(float(masks["blocks"][last]["wup_r"].mean()) - 0.25) < 1e-6


def test_module_scope_mlponly():
    cfg = get_model_config("gpt-nano-mlponly")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    masks = M.init_masks(cfg, params, key)
    bm = masks["blocks"]["2"]
    assert float(bm["wqkv_r"].mean()) == 1.0  # attention untouched
    assert abs(float(bm["wup_r"].mean()) - 0.5) < 1e-6  # MLP pruned


def test_lora_init_is_exact_noop(nano):
    """Upsample factor starts at zero ⇒ switching adapters on at the 99%
    mark must not change the function (lazy = seamless)."""
    cfg, params, masks = nano
    lora = M.init_lora(cfg, jax.random.PRNGKey(3))
    tok = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab_size)
    base = M.forward(cfg, params, masks, tok)
    with_lora = M.forward(cfg, params, masks, tok, lora=lora)
    np.testing.assert_allclose(base, with_lora, rtol=1e-4, atol=1e-5)


def test_lora_changes_output_after_update(nano):
    cfg, params, masks = nano
    lora = M.init_lora(cfg, jax.random.PRNGKey(3))
    # Nudge one upsample factor off zero.
    lora["blocks"]["1"]["wup_up"] = lora["blocks"]["1"]["wup_up"] + 0.1
    tok = jax.random.randint(jax.random.PRNGKey(4), (1, 16), 0, cfg.vocab_size)
    base = M.forward(cfg, params, masks, tok)
    pert = M.forward(cfg, params, masks, tok, lora=lora)
    assert float(jnp.abs(base - pert).max()) > 1e-5


def test_wanda_masks_nm_and_shapes(nano):
    cfg, params, _ = nano
    tok = jax.random.randint(jax.random.PRNGKey(5), (2, cfg.seq_len), 0, cfg.vocab_size)
    wmasks = M.wanda_masks(cfg, params, tok)
    bm = wmasks["blocks"]["2"]
    m = np.asarray(bm["wup_r"])
    g = m.reshape(m.shape[0], -1, 4)
    assert (g.sum(-1) == 2).all()


def test_loss_decreases_vs_random():
    """Sanity: loss at init ≈ ln(V); a few steps reduce it."""
    cfg = ModelConfig(name="t", vocab_size=64, n_layer=2, n_head=2, d_model=32,
                      d_ff=128, seq_len=32, batch_size=4)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    masks = M.init_masks(cfg, params, key)
    tok = jax.random.randint(key, (4, cfg.seq_len + 1), 0, cfg.vocab_size)
    loss = M.lm_loss(cfg, params, masks, tok)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 0.5


def test_project_params_zeroes_pruned_slots(nano):
    cfg, params, masks = nano
    proj = M.project_params(cfg, params, masks)
    w = proj["blocks"]["1"]["wup"]
    m = masks["blocks"]["1"]["wup_r"]
    assert float(jnp.abs(w * (1 - m)).max()) == 0.0
    # Kept slots unchanged.
    np.testing.assert_allclose(w * m, params["blocks"]["1"]["wup"] * m)
    # Non-weight leaves untouched.
    np.testing.assert_allclose(proj["tok_emb"], params["tok_emb"])
