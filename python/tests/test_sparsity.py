"""Mask-math correctness: N:M constraints, double pruning, Lemma 2.1,
compressed-format round trips."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile import sparsity as sp

nm = st.sampled_from([(1, 2), (2, 4), (2, 8), (4, 8), (1, 4)])


def _check_nm(mask, n, m, axis=-1):
    g = np.asarray(mask).reshape(*mask.shape[:-1], mask.shape[-1] // m, m)
    assert (g.sum(-1) <= n).all(), "N:M constraint violated"


@given(nm=nm, rows=st.sampled_from([4, 8, 16]), groups=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31))
def test_random_mask_satisfies_nm_exactly(nm, rows, groups, seed):
    n, m = nm
    mask = sp.random_nm_mask(jax.random.PRNGKey(seed), (rows, groups * m), n, m)
    _check_nm(mask, n, m)
    # Random masks keep exactly n per group (no degenerate groups).
    g = np.asarray(mask).reshape(rows, groups, m)
    assert (g.sum(-1) == n).all()


@given(nm=nm, rows=st.sampled_from([8, 16]), groups=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**31))
def test_magnitude_mask_keeps_largest(nm, rows, groups, seed):
    n, m = nm
    w = jax.random.normal(jax.random.PRNGKey(seed), (rows, groups * m))
    mask = sp.magnitude_nm_mask(w, n, m)
    _check_nm(mask, n, m)
    wg = np.abs(np.asarray(w)).reshape(rows, groups, m)
    mg = np.asarray(mask).reshape(rows, groups, m)
    for r in range(rows):
        for g in range(groups):
            kept = wg[r, g][mg[r, g] > 0]
            dropped = wg[r, g][mg[r, g] == 0]
            if len(kept) and len(dropped):
                assert kept.min() >= dropped.max() - 1e-6


@given(nm=nm, seed=st.integers(0, 2**31))
def test_double_prune_mask_is_subset_and_column_nm(nm, seed):
    n, m = nm
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (4 * m, 4 * m))
    mr = sp.random_nm_mask(k2, w.shape, n, m)
    mrc = sp.double_prune_mask(w, mr, n, m)
    # Subset: double pruning only removes.
    assert float(((mrc > 0) & (mr == 0)).sum()) == 0
    # Column-wise N:M on the *effective* backward operand.
    _check_nm(np.asarray(mrc).T, n, m)


def test_lemma21_closed_form_values():
    """Eq. 8 closed form.  Note: the paper's prose quotes 3.39% for 2:8 but
    its own Eq. 8 evaluates to 5.84%; we match the equation (and Monte
    Carlo) and record the discrepancy in EXPERIMENTS.md."""
    assert abs(sp.imposed_sparsity(1, 2) - 0.125) < 1e-12
    assert abs(sp.imposed_sparsity(2, 4) - 0.09375) < 1e-12
    assert abs(sp.imposed_sparsity(2, 8) - 0.05843) < 1e-4


@given(nm=st.sampled_from([(1, 2), (2, 4)]), seed=st.integers(0, 2**31))
def test_lemma21_monte_carlo(nm, seed):
    """Random-mask double pruning matches the Lemma 2.1 expectation.

    Uses a *random* column mask (the lemma's setting: positions are
    uniform) rather than magnitude selection.
    """
    n, m = nm
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    d = 32 * m
    w = jax.random.normal(k1, (d, d))
    mr = sp.random_nm_mask(k2, w.shape, n, m)
    # Column-wise random N:M prune of the row-pruned matrix: keep top-n of
    # |w*mr| + noise per column group — with iid noise dominating, kept
    # positions are uniform among the group, matching the lemma.
    noise = jax.random.uniform(k3, w.shape)
    scores = (mr * (1.0 + noise)).T  # nonzeros always beat zeros
    mc = sp._topn_group_mask(scores, n, m).T * mr
    measured = float(sp.density(mr) - sp.density(mc * w + 0.0 * w))
    measured = float(jnp.mean(mr) - jnp.mean(mc))
    expected = sp.imposed_sparsity(n, m)
    assert abs(measured - expected) < 0.02


@given(nm=nm, rows=st.sampled_from([4, 8]), groups=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**31))
def test_compress_roundtrip(nm, rows, groups, seed):
    n, m = nm
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (rows, groups * m))
    mask = sp.random_nm_mask(k2, w.shape, n, m)
    vals, idx = sp.compress_nm(w * mask, mask, n, m)
    assert vals.shape == (rows, groups * n)
    back = sp.decompress_nm(vals, idx, groups * m)
    np.testing.assert_allclose(back, w * mask, rtol=1e-6, atol=1e-7)
    # Indices must be strictly increasing within each group and in range.
    ig = np.asarray(idx).reshape(rows, groups, n)
    assert (np.diff(ig, axis=-1) > 0).all()
    assert (ig >= 0).all() and (ig < groups * m).all()


@given(nm=nm, rows=st.sampled_from([1, 4, 8]), groups=st.sampled_from([2, 3, 5]),
       seed=st.integers(0, 2**31))
def test_packed_offsets_roundtrip(nm, rows, groups, seed):
    """Eq.-7 bit-packing of compress_nm indices round-trips exactly
    (odd group counts exercise partially-filled tail bytes)."""
    n, m = nm
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (rows, groups * m))
    mask = sp.random_nm_mask(k2, w.shape, n, m)
    _, idx = sp.compress_nm(w * mask, mask, n, m)
    packed = sp.pack_nm_offsets(idx, n, m)
    kc = groups * n
    assert packed.shape == (rows, sp.row_meta_bytes(kc, m))
    assert packed.dtype == np.uint8
    back = sp.unpack_nm_offsets(packed, kc, n, m)
    np.testing.assert_array_equal(back, np.asarray(idx, dtype=np.int32))


def test_packed_offsets_golden_bytes_match_rust_layout():
    """Byte-layout pin shared with the rust side (sparsity::compressed
    tests): 2:4 offsets [1, 3 | 0, 2] pack LSB-first into 0b10_00_11_01."""
    idx = np.array([[1, 3, 4 + 0, 4 + 2]], dtype=np.int32)  # two 2:4 groups
    packed = sp.pack_nm_offsets(idx, 2, 4)
    assert packed.shape == (1, 1)
    assert packed[0, 0] == 0b10001101, f"got {packed[0, 0]:#010b}"
    # 2:8 (3-bit offsets) straddles byte boundaries: offsets [5, 7 | 1, 6]
    # → byte0 = 5 | 7<<3 | (1&1)<<6 = 0b01111101, byte1 = 6<<1 = 0b1100.
    idx8 = np.array([[5, 7, 8 + 1, 8 + 6]], dtype=np.int32)
    packed8 = sp.pack_nm_offsets(idx8, 2, 8)
    assert packed8.shape == (1, 2)
    assert packed8[0, 0] == 0b01111101, f"got {packed8[0, 0]:#010b}"
    assert packed8[0, 1] == 0b00001100, f"got {packed8[0, 1]:#010b}"
    # offset_bits mirrors NmScheme::offset_bits.
    assert [sp.offset_bits(m) for m in (1, 2, 4, 8, 6)] == [0, 1, 2, 3, 3]


def test_wanda_mask_uses_activation_scaling():
    """A column with huge activation norm must survive even with small |w|."""
    w = jnp.ones((4, 8)) * 0.1
    w = w.at[:, 0].set(0.01)  # tiny weight...
    act = jnp.ones((8,)).at[0].set(100.0)  # ...huge activation
    mask = sp.wanda_nm_mask(w, act, 2, 4)
    assert (np.asarray(mask)[:, 0] == 1).all()
    _check_nm(mask, 2, 4)
